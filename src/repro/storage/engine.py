"""MicroNN: the embeddable engine facade (paper Fig. 1).

Ties together the durable SQLite tier, the device-resident IVF index, the
index monitor, and the hybrid query optimizer -- the public API an
application links against:

    eng = MicroNN(dim=128, n_attr=2)
    with eng.session() as s:         # batched writes: ONE transaction
        s.upsert(ids, vecs, attrs)
        s.delete(stale_ids)
    eng.build()                      # initial clustering
    rs = eng.query(q, Q.knn(k=100).probe(8))
    rs = eng.query(q, Q.knn(k=10).where(Pred(0, "==", 3.0)))
    eng.maintain(until_idle=True)    # drain incremental maintenance
    eng.maintain_step()              # ... or one bounded quantum at a time

`query(vecs, spec)` is the ONE query entry point: the frozen QuerySpec
(core/query.py) routes resident / paged / hybrid-optimized execution and
doubles as the executor's jit cache key; every path returns a ResultSet.
`search(...)` survives as a deprecation-free kwarg shim over spec
construction.

Writes are serialised (single writer, paper §3.6); every write lands in
SQLite (durable, WAL) *and* in the device index (delta-store), so readers
see updates immediately while the host copy guarantees recoverability --
`MicroNN.recover()` rebuilds device state from SQLite after a crash.
`session()` batches a write burst into one SQLite transaction, one
delta-encode batch, and one deferred pager-invalidation pass at commit.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import delta as delta_ops
from ..core import executor, ivf, kmeans, maintenance, quantize
from ..core.hybrid import AttributeStats, Node
from ..core.monitor import IndexMonitor, MonitorConfig, WorkItem
from ..core.optimizer import HybridOptimizer
from ..core.query import Q, QuerySpec, ResultSet
from ..core.types import (INVALID_ID, DeltaStore, IVFConfig, IVFIndex,
                          PagedIndex, SearchResult, effective_pad_to,
                          normalize_if_cosine)
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace
from . import pager
from .scheduler import MaintenanceScheduler, StepReport
from .store import VectorStore


def _locked(fn):
    """Run the method under the engine's write mutex (`self.lock`).

    Applied to every durable-state writer so a session commit, a direct
    upsert/delete, and a maintenance quantum (foreground or daemon) can
    never interleave partial transactions; re-entrant, so locked paths
    may nest (upsert -> maintain(force="flush"))."""
    import functools

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self.lock:
            return fn(self, *args, **kwargs)
    return wrapper


class WriteSession:
    """Batched write scope: `with db.session() as s: s.upsert(...);
    s.delete(...)`.

    Ops are buffered and coalesced (last write per asset id wins) until
    the `with` block exits cleanly, then committed as ONE unit: one
    SQLite transaction (the durable all-or-nothing boundary), one
    delta-encode batch (a single delta upsert call encodes every new row
    in one pass, instead of one encode per call), and one deferred
    pager-invalidation pass (paged mode drops each touched partition's
    frame exactly once, however many session ops touched it). An
    exception inside the block discards the session -- nothing lands.
    """

    def __init__(self, engine: "MicroNN"):
        self._engine = engine
        self._ops: List[tuple] = []
        self._closed = False

    # -- buffered write ops --------------------------------------------------
    def upsert(self, ids: np.ndarray, vecs: np.ndarray,
               attrs: Optional[np.ndarray] = None):
        assert not self._closed, "session already committed/discarded"
        n_attr = self._engine.store.n_attr
        attrs = np.zeros((len(ids), n_attr), np.float32) if attrs is None \
            else np.array(attrs, np.float32, copy=True)
        self._ops.append(("up", np.array(ids, np.int64, copy=True),
                          np.array(vecs, np.float32, copy=True), attrs))

    def delete(self, ids: np.ndarray):
        assert not self._closed, "session already committed/discarded"
        self._ops.append(("del", np.array(ids, np.int64, copy=True)))

    # -- lifecycle -----------------------------------------------------------
    def commit(self):
        assert not self._closed, "session already committed/discarded"
        self._closed = True
        if self._ops:
            self._engine._commit_session(self._ops)
        self._ops = []

    def discard(self):
        self._closed = True
        self._ops = []

    def __enter__(self) -> "WriteSession":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.commit()
        else:
            self.discard()
        return False


class MicroNN:
    def __init__(self, dim: int, n_attr: int = 0, path: str = ":memory:",
                 config: Optional[IVFConfig] = None,
                 monitor: Optional[MonitorConfig] = None,
                 quantize: Optional[str] = None,
                 rerank_factor: Optional[int] = None,
                 memory_budget_mb: Optional[float] = None,
                 max_rows_per_step: int = 4096,
                 trace_ring_capacity: int = 256,
                 slow_query_ms: float = 100.0,
                 frame_pool=None,
                 tenant: Optional[str] = None):
        """`quantize="int8"` turns on the scalar-quantized tier: searches
        scan int8 codes and rerank `rerank_factor * k` candidates at
        float32. Both knobs land in IVFConfig (explicit kwargs override a
        passed config); codes are durable in the SQLite `codes` table.

        `memory_budget_mb` switches the engine to the paper's actual
        *disk-resident* mode: the scan tier (int8 codes when quantized,
        f32 vectors otherwise) is never fully uploaded -- it stays in
        SQLite and is paged on demand into a budget-bounded frame pool
        (storage/pager.PartitionCache), with the rerank gathering f32
        rows straight from disk. Resident memory is then O(budget +
        centroids + delta) instead of O(collection).

        `max_rows_per_step` bounds the incremental maintenance
        scheduler's work quantum: one `maintain_step()` (or one step of
        `maintain(until_idle=True)`) touches at most this many rows.

        `frame_pool` + `tenant` (PR 9 fleet mode, paged only): page
        partitions through a SHARED `fleet.pool.FramePool` instead of a
        private one -- this engine's frames then compete with every
        co-tenant's under the pool's global CLOCK and ONE fleet-wide
        byte budget. `tenant` is the stable name identifying this
        engine's frames (and its metrics label), so a spilled/reopened
        tenant resumes its cumulative series. Normally wired up by
        `fleet.Fleet`, not called directly."""
        # Engine-level write mutex (PR 7): EVERY durable-state writer --
        # upsert/delete, session commits, build/recover, and each
        # maintenance quantum (hand-cranked or the scheduler daemon's) --
        # holds this RLock, so concurrent writers can no longer
        # interleave partial transactions on the shared
        # check_same_thread=False connection. Reads never take it:
        # resident queries execute against an immutable index-pytree
        # snapshot, paged queries go through the RLock'd PartitionCache
        # and the store's WAL snapshot read connection. Re-entrant
        # because write paths nest (upsert -> maintain(force="flush")).
        self.lock = threading.RLock()
        self.store = VectorStore(path, dim=dim, n_attr=n_attr)
        cfg = config or IVFConfig(dim=dim)
        if quantize is not None:
            cfg = dataclasses.replace(cfg, quantize=quantize)
        if rerank_factor is not None:
            cfg = dataclasses.replace(cfg, rerank_factor=rerank_factor)
        self.config = cfg
        self.monitor = IndexMonitor(monitor)
        if memory_budget_mb is not None:
            assert memory_budget_mb > 0, memory_budget_mb
        self.memory_budget_mb = memory_budget_mb
        if frame_pool is not None:
            assert memory_budget_mb is not None, \
                "a shared frame pool implies paged mode: pass " \
                "memory_budget_mb"
            assert tenant is not None, \
                "a shared frame pool needs a stable tenant name"
        self._frame_pool = frame_pool
        self.tenant = None if tenant is None else str(tenant)
        self.index = None   # IVFIndex (resident) or PagedIndex (paged)
        self.optimizer: Optional[HybridOptimizer] = None
        self.maintenance_log = []
        # observability (PR 8): this engine's labeled view into the ONE
        # process metrics registry -- the pager, scheduler, and front door
        # all hang their counters off sub-scopes of it, so stats() is a
        # derived view of a single source of truth -- plus the trace ring:
        # the last N QueryTraces and maintenance events, with a slow-query
        # log above `slow_query_ms`.
        # fleet tenants label their scope by NAME (not a fresh instance
        # id): a spilled tenant reopened later lands on the same series,
        # so per-tenant counters stay cumulative across its lifetimes
        if self.tenant is not None:
            self.metrics = obs_metrics.default_registry().scope(
                component="engine", tenant=self.tenant)
        else:
            self.metrics = obs_metrics.default_registry().scope(
                component="engine", inst=str(obs_metrics.next_instance()))
        self.traces = obs_trace.TraceRing(capacity=trace_ring_capacity,
                                          slow_ms=slow_query_ms)
        self._c_queries = self.metrics.counter("queries")
        # per-tenant query-latency histogram (fleet mode only): the SLO
        # layer's burn-rate source (Fleet.health()). Solo engines keep
        # the untimed hot path -- `_h_query_s is None` is one branch
        self._h_query_s = self.metrics.histogram("query_s") \
            if self.tenant is not None else None
        self.scheduler = MaintenanceScheduler(
            self, max_rows_per_step=max_rows_per_step,
            metrics=self.metrics.scope(component="scheduler"))
        # serving front door attached to this engine (if any) -- set by
        # serving.frontdoor.FrontDoor so stats() can surface its counters
        self._frontdoor = None

    @property
    def paged(self) -> bool:
        return self.memory_budget_mb is not None

    # -- lifecycle -----------------------------------------------------------
    @_locked
    def build(self):
        """Initial clustering from the durable tier (mini-batch k-means
        streams from SQLite -- never the full dataset in memory). With
        quantize="int8" the build also trains the quantizer from the
        store's rows (build_index trains min/max on the same data, so no
        second pass over SQLite) and persists codes + stats durably
        *before* the clustering swap: after a crash at any point the
        codes table is always decode-consistent with the stored qstats.
        """
        if self.paged:
            self._build_paged()
            return
        ids, _, vecs = self.store.all_rows()
        attrs = self.store.attributes_for(ids)
        self.index = ivf.build_index(
            vecs, ids.astype(np.int32), attrs, cfg=self.config)
        self._persist_codes()
        # persist the clustering back to the clustered table
        assign = self._current_assignment()
        self.store.set_partitions(ids, assign[ids], *self._centroid_state())
        self._persist_maintenance_state()
        self._refresh_stats()

    @_locked
    def recover(self):
        """Rebuild device state from SQLite after a crash/restart."""
        if self.paged:
            self._recover_paged()
            return
        ids, parts, vecs = self.store.all_rows()
        attrs = self.store.attributes_for(ids)
        cents, csizes = self.store.centroids()
        if len(cents) == 0:
            # No durable clustering: drop *all* derived state. A stale
            # index/optimizer pair from a previous build must not keep
            # answering (hybrid) queries for a store that no longer backs
            # them.
            self.index = None
            self.optimizer = None
            return
        live = parts >= 0
        # the durable tier stores raw rows; the packed device index (and
        # the code tier) hold metric-normalised ones -- normalise the
        # main-tier rows before packing so recovery reproduces exactly
        # what build() put on device. Pending delta rows stay raw here:
        # the replay upsert below normalises them itself, exactly once,
        # like the live engine's write path did.
        vecs_live = np.asarray(normalize_if_cosine(
            jnp.asarray(vecs[live], jnp.float32), self.config.metric))
        qstats = None
        codes_live = None
        if self.config.quantize == "int8":
            qs = self.store.qstats()
            if qs is not None:
                # codes were persisted at build/upsert time: restore them
                # without re-encoding (the durable tier is authoritative);
                # rows missing a durable code (e.g. written by a pre-
                # quantization engine) are re-encoded from float32
                qstats = quantize.stats_from_arrays(*qs)
                codes_live, found = self.store.codes_for(ids[live])
                if not found.all():
                    codes_live[~found] = quantize.encode_np(
                        qstats, vecs_live[~found])
        packed = ivf.pack_partitions(
            vecs_live, ids[live].astype(np.int32), attrs[live],
            parts[live].astype(np.int64), len(cents),
            pad_to=effective_pad_to(self.config), codes=codes_live)
        vec, vid, vat, val, counts, cod = packed
        idx = IVFIndex(
            centroids=jnp.asarray(cents), csizes=jnp.asarray(csizes),
            vectors=jnp.asarray(vec), ids=jnp.asarray(vid),
            attrs=jnp.asarray(vat), valid=jnp.asarray(val),
            counts=jnp.asarray(counts),
            delta=DeltaStore.empty(self.config.delta_capacity, self.store.dim,
                                   attrs.shape[1],
                                   quantized=cod is not None),
            base_mean_size=jnp.asarray(max(counts.mean(), 1.0), jnp.float32),
            codes=None if cod is None else jnp.asarray(cod),
            qstats=qstats,
            code_norms=None if cod is None else quantize.row_norms(
                qstats, jnp.asarray(cod)),
            drift=jnp.zeros((len(cents),), jnp.float32),
            config=self.config)
        # restore the monitor's maintenance signals (drift accumulators +
        # rebuild baseline) persisted alongside the clustering -- a
        # recovered index resumes maintenance where the crash left off
        mstate = self.store.maintenance_state()
        if mstate is not None:
            base, drift = mstate
            if drift.shape[0] == len(cents):
                idx = dataclasses.replace(
                    idx, drift=jnp.asarray(drift, jnp.float32),
                    base_mean_size=jnp.asarray(base, jnp.float32))
        self.index = idx
        # replay delta rows (partition -1); upsert re-encodes them into
        # the delta's code block from the same stats, deterministically.
        # Replay in capacity-sized chunks with a flush in between -- the
        # store may hold more pending rows than the delta can seat (the
        # delta scatter would silently drop the overflow otherwise).
        if (~live).any():
            rv = vecs[~live]
            ri = ids[~live].astype(np.int32)
            ra = attrs[~live]
            cap = self.config.delta_capacity
            for s in range(0, len(rv), cap):
                e = min(s + cap, len(rv))
                if delta_ops.delta_free_slots(self.index) < e - s:
                    self.maintain(force="flush")
                self.index = delta_ops.upsert(
                    self.index, jnp.asarray(rv[s:e]), jnp.asarray(ri[s:e]),
                    jnp.asarray(ra[s:e]))
        self._refresh_stats()

    # -- writes ---------------------------------------------------------------
    @_locked
    def upsert(self, ids: np.ndarray, vecs: np.ndarray,
               attrs: Optional[np.ndarray] = None):
        n_attr = self.store.n_attr
        attrs = np.zeros((len(ids), n_attr), np.float32) if attrs is None \
            else attrs
        old_main = None
        if self.paged and self.index is not None:
            # paged mode has no resident main-tier ids to tombstone: note
            # which partitions hold stale copies BEFORE the durable upsert
            # moves them, then invalidate those frames. Unique ids only --
            # a duplicated id in the batch still removes one durable row,
            # so it must decrement its partition's count exactly once.
            old = self.store.partitions_for(np.unique(np.asarray(ids)))
            old_main = old[old >= 0]
        self.store.upsert(ids, vecs, attrs, partition_id=-1)
        if self.index is None:
            return
        if self.paged:
            if old_main is not None and old_main.size:
                self.index.cache.invalidate(np.unique(old_main))
                self.index.counts = self.index.counts - np.bincount(
                    old_main, minlength=self.index.k)
            if delta_ops.delta_free_slots(self.index) < len(ids):
                self.maintain(force="flush")
            self.index.delta = delta_ops.delta_only_upsert(
                self.index.delta, jnp.asarray(vecs, jnp.float32),
                jnp.asarray(ids, jnp.int32), jnp.asarray(attrs, jnp.float32),
                self.config.metric, self.index.qstats)
            return
        if delta_ops.delta_free_slots(self.index) < len(ids):
            self.maintain(force="flush")
        self.index = delta_ops.upsert(
            self.index, jnp.asarray(vecs, jnp.float32),
            jnp.asarray(ids, jnp.int32), jnp.asarray(attrs, jnp.float32))
        # NB: no durable code write here -- pending (partition -1) rows are
        # replayed through delta_ops.upsert on recover(), which re-encodes
        # them deterministically; their durable codes are first written by
        # the next build()/rebuild's _persist_codes.

    @_locked
    def delete(self, ids: np.ndarray):
        old_main = None
        if self.paged and self.index is not None:
            # unique ids: one durable row removed -> one count decrement
            old = self.store.partitions_for(np.unique(np.asarray(ids)))
            old_main = old[old >= 0]
        self.store.delete(ids)
        if self.index is None:
            return
        if self.paged:
            if old_main is not None and old_main.size:
                self.index.cache.invalidate(np.unique(old_main))
                self.index.counts = self.index.counts - np.bincount(
                    old_main, minlength=self.index.k)
            self.index.delta = delta_ops.delta_only_delete(
                self.index.delta, jnp.asarray(ids, jnp.int32))
            return
        self.index = delta_ops.delete(self.index,
                                      jnp.asarray(ids, jnp.int32))

    def session(self) -> WriteSession:
        """Open a batched write session: buffered upserts/deletes commit
        as one SQLite transaction + one delta-encode batch + one deferred
        pager-invalidation pass when the `with` block exits cleanly."""
        return WriteSession(self)

    @_locked
    def _commit_session(self, ops: List[tuple]):
        """Apply a session's coalesced net effect atomically (single
        writer, paper §3.6). Per-id last-write-wins: an upsert overridden
        by a later delete never lands, and vice versa -- matching what
        sequential upsert()/delete() calls would have left behind."""
        # vectorized last-write-wins coalescing: concatenate every op's
        # ids in order and keep each id's LAST occurrence (reverse +
        # np.unique-first-hit) -- no per-row Python loop, so a bulk-load
        # session coalesces at array speed
        id_chunks, kind_chunks, row_chunks = [], [], []
        vec_chunks, attr_chunks = [], []
        row_off = 0
        for op in ops:
            if op[0] == "up":
                _, ids, vecs, attrs = op
                row_chunks.append(row_off + np.arange(len(ids)))
                vec_chunks.append(vecs)
                attr_chunks.append(attrs)
                row_off += len(ids)
                kind_chunks.append(np.ones(len(ids), bool))
            else:
                ids = op[1]
                row_chunks.append(np.full(len(ids), -1))
                kind_chunks.append(np.zeros(len(ids), bool))
            id_chunks.append(ids)
        ids_all = np.concatenate(id_chunks)
        kind_all = np.concatenate(kind_chunks)       # True = upsert
        rows_all = np.concatenate(row_chunks)
        _, first_rev = np.unique(ids_all[::-1], return_index=True)
        last = len(ids_all) - 1 - first_rev          # last op per id
        is_up = kind_all[last]
        up_ids = ids_all[last[is_up]]
        del_ids = ids_all[last[~is_up]]
        vecs_all = np.concatenate(vec_chunks) if vec_chunks \
            else np.zeros((0, self.store.dim), np.float32)
        attrs_all = np.concatenate(attr_chunks) if attr_chunks \
            else np.zeros((0, self.store.n_attr), np.float32)
        up_vecs = vecs_all[rows_all[last[is_up]]]
        up_attrs = attrs_all[rows_all[last[is_up]]]
        touched = np.concatenate([up_ids, del_ids])
        old_main = None
        if self.paged and self.index is not None:
            # partitions holding stale copies, noted BEFORE the durable
            # write moves/removes them -- invalidated once, at commit
            old = self.store.partitions_for(touched)
            old_main = old[old >= 0]
        with self.store.transaction():    # ONE durable transaction
            if len(up_ids):
                self.store.upsert(up_ids, up_vecs, up_attrs, partition_id=-1)
            if len(del_ids):
                self.store.delete(del_ids)
        if self.index is None:
            return
        if self.paged:
            if old_main is not None and old_main.size:
                # the single deferred invalidation pass
                self.index.cache.invalidate(np.unique(old_main))
                self.index.counts = self.index.counts - np.bincount(
                    old_main, minlength=self.index.k)
            if len(del_ids):
                self.index.delta = delta_ops.delta_only_delete(
                    self.index.delta, jnp.asarray(del_ids, jnp.int32))
        elif len(del_ids):
            self.index = delta_ops.delete(self.index,
                                          jnp.asarray(del_ids, jnp.int32))
        # one delta-encode batch: a single append call quantizes every
        # new row in one encode (chunked only past the delta capacity)
        self._delta_append(up_ids, up_vecs, up_attrs)

    def _delta_append(self, ids: np.ndarray, vecs: np.ndarray,
                      attrs: np.ndarray):
        """Append rows to the device delta in capacity-sized chunks,
        flushing when full -- the shared tail of upsert and session
        commit in both modes."""
        cap = self.config.delta_capacity
        for s in range(0, len(ids), cap):
            e = min(s + cap, len(ids))
            if delta_ops.delta_free_slots(self.index) < e - s:
                self.maintain(force="flush")
            if self.paged:
                self.index.delta = delta_ops.delta_only_upsert(
                    self.index.delta, jnp.asarray(vecs[s:e]),
                    jnp.asarray(ids[s:e].astype(np.int32)),
                    jnp.asarray(attrs[s:e]),
                    self.config.metric, self.index.qstats)
            else:
                self.index = delta_ops.upsert(
                    self.index, jnp.asarray(vecs[s:e]),
                    jnp.asarray(ids[s:e].astype(np.int32)),
                    jnp.asarray(attrs[s:e]))

    # -- maintenance ----------------------------------------------------------
    @_locked
    def maintain(self, force: Optional[str] = None,
                 until_idle: bool = False,
                 max_steps: Optional[int] = None):
        """Run maintenance.

        `maintain(until_idle=True)` is the steady-state path (PR 5): the
        budgeted scheduler drains the monitor's work queue -- partial
        delta flushes, 2-means splits of oversized partitions, merges of
        underfull siblings, local reclustering of drifted neighbourhoods
        -- in `max_rows_per_step` quanta, never a full rebuild. Returns
        the list of StepReports executed.

        `maintain(force="flush"|"rebuild")` and the legacy no-arg form
        (single monitor verdict) are kept for whole-index maintenance;
        `full_rebuild` remains the escape hatch, not the steady state.
        """
        if self.index is None:
            return [] if until_idle else None
        if until_idle:
            assert force is None, "until_idle excludes force"
            return self.scheduler.drain(max_steps=max_steps)
        if self.paged:
            return self._maintain_paged(force)
        health = self.monitor.check(self.index)
        action = force or health.action
        if action == "flush":
            self.index, stats = maintenance.flush_delta(self.index)
            self.maintenance_log.append(stats)
            self.store.update_centroids(np.asarray(self.index.centroids),
                                        np.asarray(self.index.csizes))
            self._persist_maintenance_state()
            return "flush"
        if action == "rebuild":
            self.index, stats = maintenance.full_rebuild(self.index)
            self.maintenance_log.append(stats)
            # a rebuild retrains the quantizer -> every code changes;
            # persist codes+stats before the clustering swap (same crash
            # ordering as build())
            self._persist_codes()
            ids, _, _ = self.store.all_rows()
            assign = self._current_assignment()
            self.store.set_partitions(
                ids, assign[ids], *self._centroid_state())
            self._persist_maintenance_state()
            self._refresh_stats()
            return "rebuild"
        return None

    @_locked
    def maintain_step(self) -> Optional[StepReport]:
        """One bounded maintenance quantum (<= max_rows_per_step rows):
        pops the highest-priority item off the monitor's work queue and
        executes it. Queries issued between steps see a consistent mixed
        old/new partition state. Returns None when the index is idle."""
        if self.index is None:
            return None
        return self.scheduler.step()

    def _execute_work_item(self, item: WorkItem,
                           max_rows: int) -> Optional[StepReport]:
        """Scheduler callback: run one work item. Returns None when the
        item plans to a no-op (the scheduler then skips it)."""
        if item.action == "flush":
            return self._flush_step(max_rows)
        if item.action == "repack":
            # device-only tombstone repack: zero durable I/O by contract
            assert not self.paged, "paged frames carry no tombstones"
            self.index = maintenance.repack_partition(
                self.index, item.pids[0])
            return StepReport("repack", item.pids, item.rows, 0)
        idx = self.index
        cents = np.asarray(idx.centroids)
        csz = np.asarray(idx.csizes)
        counts = np.asarray(idx.counts)
        fetch = self._fetch_rows_paged if self.paged \
            else self._fetch_rows_resident
        n_local = self.monitor.cfg.repair_neighbors
        if item.action == "split":
            plan = maintenance.plan_split(
                cents, csz, counts, item.pids[0], fetch,
                row_budget=max_rows,
                n_local=self.monitor.cfg.split_neighbors)
        elif item.action == "merge":
            plan = maintenance.plan_merge(
                cents, csz, counts, item.pids[0], item.pids[1], fetch)
        else:
            assert item.action == "recluster", item.action
            plan = maintenance.plan_local_recluster(
                cents, csz, counts, item.pids[0], fetch,
                row_budget=max_rows, n_local=n_local)
        if plan is None:
            return None
        return self._apply_repair(plan)

    def _flush_step(self, max_rows: int) -> StepReport:
        """A (possibly partial) delta flush as one scheduler quantum.

        Unlike the legacy device-only resident flush, the scheduler's
        flush also moves the rows *durably* (exactly what the paged flush
        does): later repairs then never pay "promotion" writes for rows
        still parked in the pending -1 partition, repair write I/O is
        pure reassignment cost, and the resident and paged engines leave
        identical durable states behind every step."""
        if self.paged:
            stats = self._paged_flush(max_rows=max_rows)
            if stats is None:
                stats = maintenance.MaintenanceStats(
                    "incremental", 0, 0, 0, self.index.cache.p_max,
                    self.index.cache.p_max)
            return StepReport("flush", (), stats.rows_moved,
                              stats.bytes_written)
        idx = self.index
        d = idx.delta
        live = np.nonzero(np.asarray(d.valid))[0]
        if max_rows is not None and live.size > max_rows:
            live = live[:max_rows]
        dids = np.asarray(d.ids)[live]
        dx = np.asarray(d.vectors)[live]      # metric-normalised
        dcod = np.asarray(d.codes)[live] if d.codes is not None else None
        assign = maintenance.assign_nearest_centroid(dx, idx.centroids) \
            if live.size else np.zeros((0,), np.int64)
        self.index, stats = maintenance.flush_delta(
            self.index, max_rows=max_rows, assign=assign)
        self.maintenance_log.append(stats)
        with self.store.transaction():        # one atomic durable flush
            if live.size and dcod is not None:
                # codes first (crash contract: byte-stable either way)
                self.store.set_code_tier(
                    dids, dcod,
                    *quantize.stats_to_arrays(self.index.qstats))
            # row moves + TOUCHED centroid rewrites only -- durable I/O
            # matches the stats accounting (never O(k) per quantum)
            touched = np.unique(assign)
            self.store.apply_repair(
                dids, assign, touched,
                np.asarray(self.index.centroids)[touched],
                np.asarray(self.index.csizes)[touched])
            self._persist_maintenance_state()
        return StepReport("flush", (), stats.rows_moved,
                          stats.bytes_written)

    # -- local repair (split / merge / recluster) -----------------------------
    def _fetch_rows_resident(self, pids):
        """RowFetch over the packed device layout (rows sorted by id, the
        same order SQLite's clustered scan yields -- bit-parity with the
        paged planner)."""
        idx = self.index
        vid = np.asarray(idx.ids)
        val = np.asarray(idx.valid)
        vec = np.asarray(idx.vectors)
        vat = np.asarray(idx.attrs)
        cod = np.asarray(idx.codes) if idx.codes is not None else None
        out = {}
        for p in pids:
            sel = np.nonzero(val[p])[0]
            ids = vid[p][sel]
            order = np.argsort(ids, kind="stable")
            out[int(p)] = maintenance.RowBlock(
                ids=ids[order].astype(np.int32),
                vecs=vec[p][sel][order],
                attrs=vat[p][sel][order],
                codes=None if cod is None else cod[p][sel][order])
        return out

    def _fetch_rows_paged(self, pids):
        """RowFetch streaming the neighbourhood from SQLite in ONE
        batched read (VectorStore.scan_partitions); rows arrive sorted by
        asset id and are metric-normalised exactly like the pager's fault
        path, so the paged planner sees the same bytes the resident
        planner reads from the packed layout."""
        idx = self.index
        counts = np.asarray(idx.counts)
        pids = [int(p) for p in pids]
        p_max = int(max(max(counts[p] for p in pids), 1))
        blocks = self.store.scan_partitions(pids, p_max, with_vecs=True)
        vecs = np.asarray(normalize_if_cosine(
            jnp.asarray(blocks.vecs, jnp.float32), self.config.metric))
        out = {}
        for j, p in enumerate(pids):
            m = int(blocks.valid[j].sum())
            out[p] = maintenance.RowBlock(
                ids=blocks.ids[j, :m].astype(np.int32),
                vecs=vecs[j, :m])
        return out

    def _apply_repair(self, plan) -> StepReport:
        """Persist + apply one RepairPlan. Durability ordering (the crash
        contract pinned by tests/test_maintenance.py): (1) quantized
        codes for the touched rows land first -- byte-stable re-encode
        under the *existing* quantizer, so they are valid under either
        clustering state; (2) the row moves + touched-centroid rewrites
        commit as ONE transaction (VectorStore.apply_repair); a crash
        between the two serves the pre-repair clustering bit-identically.
        Only then does device/paged state update."""
        idx = self.index
        quantized = idx.quantized if self.paged else idx.codes is not None
        qstats = idx.qstats
        code_bytes = 0
        if quantized and plan.rows:
            _, found = self.store.codes_for(plan.row_ids)
            if not found.all():
                missing = ~found
                enc = quantize.encode_np(qstats, plan.row_vecs[missing])
                self.store.set_code_tier(
                    plan.row_ids[missing], enc,
                    *quantize.stats_to_arrays(qstats))
                code_bytes = int(missing.sum()) * self.store.dim
        # -- atomic repair transaction: only durably-moved rows get
        # UPDATEs, only touched partitions get centroid rewrites ---------
        old_pid = self.store.partitions_for(plan.row_ids)
        movedm = old_pid != plan.assign
        k = idx.k
        cents = np.array(idx.centroids)
        csz = np.array(idx.csizes, np.float32)
        if plan.k_after > k:
            cents = np.pad(cents, [(0, plan.k_after - k), (0, 0)])
            csz = np.pad(csz, (0, plan.k_after - k))
        cents[plan.pids] = plan.centroids
        csz[plan.pids] = plan.csizes
        self.store.apply_repair(
            plan.row_ids[movedm], plan.assign[movedm], plan.pids,
            plan.centroids, plan.csizes)
        # -- device / paged state ----------------------------------------
        # write accounting counts the durably-moved rows (can exceed the
        # plan's device moves: rows promoted out of the pending -1
        # partition) plus the touched centroids' rewrite -- I/O scales
        # with the repair neighbourhood, never the collection. A moved
        # row does NOT rewrite its code (the codes table is keyed by
        # asset id and codes are byte-stable under the existing
        # quantizer) -- only backfilled codes count; a full rebuild, by
        # contrast, retrains and rewrites every code.
        n_attr = self.store.n_attr
        row_b = 4 * self.store.dim + 4 + 4 * n_attr + 1
        bytes_written = int(movedm.sum()) * row_b \
            + len(plan.pids) * self.store.dim * 4 + code_bytes
        p_max_before = idx.p_max
        if self.paged:
            self._apply_repair_paged(plan, cents, csz)
        else:
            self.index = maintenance.apply_plan(self.index, plan)
        stats = maintenance.MaintenanceStats(
            kind=plan.kind, rows_moved=int(movedm.sum()),
            partitions_touched=len(plan.pids),
            bytes_written=bytes_written,
            p_max_before=p_max_before, p_max_after=self.index.p_max)
        self.maintenance_log.append(stats)
        self._persist_maintenance_state()
        return StepReport(plan.kind, tuple(int(p) for p in plan.pids),
                          plan.rows, bytes_written)

    def _apply_repair_paged(self, plan, cents: np.ndarray,
                            csz: np.ndarray):
        """Paged-mode apply: the durable tier is the scan tier, so the
        repair is already applied -- update resident metadata (centroids,
        counts, drift), invalidate exactly the touched frames, and grow
        the frame geometry if a merge outgrew p_max."""
        idx = self.index
        k = idx.k
        counts = np.array(idx.counts)
        drift = np.array(idx.drift, np.float32) if idx.drift is not None \
            else np.zeros((k,), np.float32)
        if plan.k_after > k:
            counts = np.pad(counts, (0, plan.k_after - k))
            drift = np.pad(drift, (0, plan.k_after - k))
        sizes = np.asarray([(plan.assign == p).sum() for p in plan.pids])
        counts[plan.pids] = sizes
        drift[plan.pids] = 0.0
        idx.centroids = jnp.asarray(cents)
        idx.csizes = jnp.asarray(csz, jnp.float32)
        idx.counts = counts
        idx.drift = drift
        cache = idx.cache
        cache.invalidate([int(p) for p in plan.pids])
        pad = effective_pad_to(self.config)
        new_p_max = int(max(sizes.max() if sizes.size else 1, 1))
        new_p_max = max(cache.p_max, -(-new_p_max // pad) * pad)
        if new_p_max > cache.p_max:
            cache.resize(new_p_max)

    # -- queries --------------------------------------------------------------
    def query(self, queries: np.ndarray, spec: Optional[QuerySpec] = None,
              *, trace: bool = False) -> ResultSet:
        """THE query entry point: execute a declarative QuerySpec.

        `trace=True` activates a per-query QueryTrace for this call: every
        layer the query crosses (planner, probe, pager, fused scan,
        rerank, merge) records a stage span, the trace lands in the
        engine's ring (`self.traces`, incl. the slow-query log) and rides
        back on `result.trace`. With `trace=False` (default) no span is
        allocated -- unless an OUTER trace is already active on this
        thread (the front door's shared fused-call trace), in which case
        the layers keep recording into that one.

        The spec alone routes execution -- resident fused scan, paged
        frame-pool streaming, or the hybrid pre/post-filter choice (the
        optimizer resolves `hybrid='auto'` into a concrete pre/post spec,
        both arms still spec-routed) -- and, being frozen + hashable, it
        is also the executor's jit cache key: issuing an equal spec twice
        never retraces. Returns a ResultSet (ids + exact-f32 scores,
        optional gathered attrs when `spec.with_attrs()`).

        Thread-safety: queries never take the engine write mutex. The
        index reference is read ONCE -- resident repairs rebind
        `self.index` to a new immutable pytree, so an in-flight query
        keeps scanning its consistent snapshot; paged execution is
        protected by the PartitionCache RLock (deferred pinned-frame
        invalidation) and the store's WAL snapshot read connection."""
        # flight-recorder hook (PR 10): recording-off cost is this one
        # global load + branch (plus the fleet-mode SLO histogram
        # check), preserving the <=3% off-path gate in bench_obs
        rec = obs_recorder._ACTIVE
        if rec is None and self._h_query_s is None:
            if not (trace and obs_trace.enabled()):
                return self._query_inner(queries, spec)
            return self._query_traced(queries, spec)
        t0 = time.perf_counter()
        if not (trace and obs_trace.enabled()):
            res = self._query_inner(queries, spec)
        else:
            res = self._query_traced(queries, spec)
        if self._h_query_s is not None:
            self._h_query_s.observe(time.perf_counter() - t0)
        if rec is not None:
            rec.record(obs_recorder.SITE_ENGINE, self.tenant, queries,
                       spec, result=res)
        return res

    def _query_traced(self, queries: np.ndarray,
                      spec: Optional[QuerySpec]) -> ResultSet:
        tr = obs_trace.QueryTrace(
            mode="paged" if self.paged else "resident")
        with obs_trace.activate(tr):
            res = self._query_inner(queries, spec)
        tr.finish()
        tr.result = res
        res.trace = tr
        self.traces.append(tr)
        return res

    def explain(self, queries: np.ndarray,
                spec: Optional[QuerySpec] = None) -> obs_trace.QueryTrace:
        """Execute the query traced and return the QueryTrace (the result
        rides on `trace.result`): the per-stage wall-time + work-counter
        breakdown for this exact spec on this exact engine mode."""
        return self.query(queries, spec, trace=True).trace

    def _query_inner(self, queries: np.ndarray,
                     spec: Optional[QuerySpec]) -> ResultSet:
        idx, optimizer = self.index, self.optimizer
        assert idx is not None, "build() or recover() first"
        spec = QuerySpec() if spec is None else spec
        q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        self._c_queries.inc()
        spec = self._resolve_spec_traced(idx, optimizer, spec,
                                         int(q.shape[0]))
        res = executor.run(idx, q, spec)
        if spec.gather_attrs and self.store.n_attr:
            res.attrs = self._gather_attrs(np.asarray(res.ids))
        return res

    def query_batched(self, chunks: List[np.ndarray],
                      spec: Optional[QuerySpec] = None) -> List[ResultSet]:
        """Cross-request micro-batch entry point (the serving front
        door's fused call): per-caller query chunks sharing ONE spec are
        concatenated, executed as a single bucketed run -- one fused
        scan, one jit cache entry -- and split back into per-caller
        ResultSets. Results are bit-identical to issuing each chunk
        through `query()` alone: the spec resolves once (the optimizer
        rewrite depends only on spec + stats, not on the query vectors)
        and `executor.run_coalesced` slices the batch mechanically."""
        idx, optimizer = self.index, self.optimizer
        assert idx is not None, "build() or recover() first"
        spec = QuerySpec() if spec is None else spec
        self._c_queries.inc(len(chunks))
        spec = self._resolve_spec_traced(
            idx, optimizer, spec, sum(int(np.atleast_2d(c).shape[0])
                                      for c in chunks))
        results = executor.run_coalesced(idx, chunks, spec)
        if spec.gather_attrs and self.store.n_attr:
            for rs in results:
                rs.attrs = self._gather_attrs(np.asarray(rs.ids))
        return results

    def _resolve_spec_traced(self, idx, optimizer, spec: QuerySpec,
                             n_queries: int) -> QuerySpec:
        """Spec resolution with the trace's `plan` span: records the
        hybrid pre/post decision and the resolved shape when a trace is
        active (no-op otherwise -- one thread-local lookup)."""
        tr = obs_trace.current()
        if tr is None:
            return self._resolve_spec(idx, optimizer, spec)
        t0 = time.perf_counter()
        spec = self._resolve_spec(idx, optimizer, spec)
        tr.record(obs_trace.STAGE_PLAN,
                  (time.perf_counter() - t0) * 1e3,
                  kind=spec.kind, k=int(spec.k),
                  n_probe=int(spec.n_probe), hybrid=spec.hybrid,
                  predicate=spec.predicate is not None)
        tr.spec = spec
        tr.n_queries += n_queries
        return spec

    def _resolve_spec(self, idx, optimizer, spec: QuerySpec) -> QuerySpec:
        """Resolve the hybrid pre/post choice (and/or size the prefilter
        cap) from the selectivity estimate (paper Eqs. 1-3). Opaque
        hand-written filter callables skip the optimizer (nothing to
        estimate) and run as fused post-filters."""
        if not self.paged and spec.predicate_tree is not None \
                and spec.kind == "ann" \
                and (spec.hybrid == "auto"
                     or (spec.hybrid == "pre" and spec.cap is None)):
            spec, _ = optimizer.plan_spec(idx, spec)
        return spec

    def search(self, queries: np.ndarray, k: int = 100, n_probe: int = 8,
               predicate: Optional[Node] = None, exact: bool = False,
               batch_mqo: Optional[bool] = None,
               backend: Optional[str] = None) -> ResultSet:
        """Deprecation shim: kwargs -> QuerySpec -> query(). Kept so
        existing callers survive the API redesign; new code should build
        specs (`Q.knn(...)...`) and call `query()` directly. `batch_mqo`
        is dead -- a batched ANN spec *is* the MQO shared scan (same
        union + selection mask) -- and warns. One deliberate semantic
        fix vs the old engine: `exact=True` + `predicate` now runs the
        filtered exact oracle (the old code silently ignored `exact`
        and let the optimizer answer approximately)."""
        if batch_mqo is not None:
            warnings.warn(
                "MicroNN.search(batch_mqo=...) is deprecated and has no "
                "effect: a batched ANN QuerySpec is the MQO shared scan; "
                "use MicroNN.query(vecs, Q.knn(...))",
                DeprecationWarning, stacklevel=2)
        spec = Q.exact(k=k) if exact else Q.knn(k=k, n_probe=n_probe)
        if predicate is not None:
            spec = spec.where(predicate)
        if backend is not None:
            spec = spec.backend(backend)
        return self.query(queries, spec)

    def _gather_attrs(self, ids: np.ndarray) -> np.ndarray:
        """[Q, k] result ids -> [Q, k, n_attr] attribute rows from the
        durable tier (zeros where INVALID)."""
        Qn, k = ids.shape
        flat = ids.reshape(-1)
        got = flat != INVALID_ID
        out = np.zeros((Qn * k, self.store.n_attr), np.float32)
        if got.any():
            out[got] = self.store.attributes_for(flat[got])
        return out.reshape(Qn, k, self.store.n_attr)

    # -- observability --------------------------------------------------------
    def stats(self) -> dict:
        """Operational counters with UNIFORM keys in both modes: pager
        hits/misses/evictions, resident scan-tier bytes, and the query
        executor's compile-cache counters (`trace_count`,
        `compile_cache_size` -- pinned against QuerySpecs, so a stable
        trace_count across a query stream proves the spec cache is
        hitting). In resident mode the pager counters are zero and
        `resident_bytes` is what search must keep in memory (f32 tier +
        codes when quantized); in paged mode it is the preallocated frame
        pool (<= the byte budget by construction). Benchmarks and tests
        assert on these counters instead of re-deriving them.

        PR 7 adds the serving/maintenance-concurrency counters, uniform
        in both modes: `scheduler_depth` (pending maintenance work
        items), `daemon_alive`/`daemon_steps` (the background scheduler
        thread's liveness and executed quanta), and `frontdoor` (the
        attached serving front door's admission/coalescing/latency
        counters -- queued, coalesced, batches, p50/p99 queue-wait and
        execute times; zeroed when no front door is attached).

        PR 8 makes every value here a derived view of the ONE process
        metrics registry (obs.metrics) -- same keys, same plain-int
        values -- and adds `scheduler`: the maintenance scheduler's
        wakeup / backoff / rows-moved / per-action telemetry."""
        from ..serving import frontdoor as frontdoor_mod
        sched = self.scheduler
        fd = self._frontdoor
        out = {"paged": self.paged, "hits": 0, "misses": 0, "evictions": 0,
               "resident_bytes": 0, "budget_bytes": None,
               "trace_count": executor.trace_count(),
               "compile_cache_size": executor.compile_cache_size(),
               "scheduler_depth": sched.queue_depth(),
               "daemon_alive": sched.daemon_alive,
               "daemon_steps": sched.daemon_steps,
               "scheduler": sched.stats(),
               "frontdoor": fd.stats() if fd is not None
               else frontdoor_mod.empty_stats()}
        idx = self.index
        if idx is None:
            return out
        if self.paged:
            out.update(idx.cache.stats())
            return out
        # same components the paged pool counts: payload(s) + ids + valid
        # + attrs, so the two modes' resident_bytes are comparable
        resident = int(idx.vectors.nbytes + idx.ids.nbytes
                       + idx.valid.nbytes + idx.attrs.nbytes)
        if idx.codes is not None:
            resident += int(idx.codes.nbytes)
        out["resident_bytes"] = resident
        return out

    # -- paged lifecycle (memory_budget_mb mode) ------------------------------
    def _build_paged(self):
        """Cluster + persist durably, then attach a paged view -- fully
        STREAMED from SQLite, so host memory stays O(batch + ids), never
        O(collection): the quantizer trains via train_from_store, codes
        encode batch-by-batch, mini-batch k-means samples from disk, the
        final assignment streams the clustered scan, and the generation
        swap moves partition ids with keyed UPDATEs instead of
        re-materialising the blobs. Same crash ordering as build():
        codes + qstats land before the clustering swap."""
        cfg = self.config
        store = self.store
        batch = max(cfg.minibatch_size, 4096)
        ids = store.iter_asset_ids()
        if cfg.quantize == "int8":
            qstats = quantize.train_from_store(store, cfg.metric, batch)

            def _code_chunks():
                off = 0
                for b in store.iter_batches(batch):
                    bn = np.asarray(normalize_if_cosine(
                        jnp.asarray(b, jnp.float32), cfg.metric))
                    yield (ids[off:off + len(bn)],
                           quantize.encode_np(qstats, bn))
                    off += len(bn)
            # one transaction for the whole stream: a crash never leaves
            # old codes paired with the retrained stats
            store.set_code_tier_streaming(
                _code_chunks(), *quantize.stats_to_arrays(qstats))
        km = kmeans.MiniBatchKMeans(cfg)
        km.fit(lambda size, rng: store.sample(size, rng), len(ids))
        assign = km.assign(store.iter_batches(batch))
        store.reassign_partitions(ids, assign, km.centroids, km.counts)
        self._attach_paged()
        # a fresh clustering resets the maintenance signals -- write them
        # so a later recover() does not restore a stale pre-build state
        self._persist_maintenance_state()

    def _attach_paged(self):
        """Build the PagedIndex view from durable metadata only: centroids,
        per-partition counts, quantizer stats, and an empty frame pool
        sized to the byte budget."""
        cfg = self.config
        cents, csizes = self.store.centroids()
        if len(cents) == 0:
            self.index = None
            self.optimizer = None
            return
        counts = self.store.partition_counts(len(cents))
        qstats, payload = None, "f32"
        if cfg.quantize == "int8":
            qs = self.store.qstats()
            if qs is not None:
                qstats = quantize.stats_from_arrays(*qs)
                payload = "int8"
        pad = effective_pad_to(cfg)
        p_max = int(max(counts.max() if len(counts) else 0, 1))
        p_max = max(pad, -(-p_max // pad) * pad)
        old_cache = self.index.cache \
            if isinstance(self.index, PagedIndex) else None
        cache = pager.PartitionCache(
            self.store, p_max=p_max,
            budget_bytes=int(self.memory_budget_mb * 2 ** 20),
            payload=payload, metric=cfg.metric, qstats=qstats,
            with_attrs=self.store.n_attr > 0,
            metrics=self.metrics.scope(component="pager"),
            pool=self._frame_pool, tenant=self.tenant)
        if old_cache is not None:   # counters are cumulative across rebuilds
            cache.hits, cache.misses, cache.evictions = \
                old_cache.hits, old_cache.misses, old_cache.evictions
        nonempty = counts[counts > 0]
        self.index = PagedIndex(
            centroids=jnp.asarray(cents),
            csizes=jnp.asarray(csizes, jnp.float32),
            counts=counts,
            delta=DeltaStore.empty(cfg.delta_capacity, self.store.dim,
                                   self.store.n_attr,
                                   quantized=payload == "int8"),
            cache=cache,
            base_mean_size=float(nonempty.mean()) if nonempty.size else 1.0,
            qstats=qstats,
            drift=np.zeros((len(cents),), np.float32),
            config=cfg)
        self.optimizer = None

    def _recover_paged(self):
        """Paged recovery restores only metadata + centroids (plus the
        pending delta rows); partitions fault in lazily on first probe."""
        self._attach_paged()
        if self.index is None:
            return
        mstate = self.store.maintenance_state()
        if mstate is not None:
            base, drift = mstate
            if drift.shape[0] == self.index.k:
                self.index.drift = np.asarray(drift, np.float32)
                self.index.base_mean_size = float(base)
        pids, pvecs = self.store.scan_partition(-1)
        if not len(pids):
            return
        attrs = self.store.attributes_for(pids)
        cap = self.config.delta_capacity
        for s in range(0, len(pids), cap):
            e = min(s + cap, len(pids))
            free = self.index.delta.capacity - int(self.index.delta.count)
            if free < e - s:
                self.maintain(force="flush")
            self.index.delta = delta_ops.delta_only_upsert(
                self.index.delta, jnp.asarray(pvecs[s:e], jnp.float32),
                jnp.asarray(pids[s:e].astype(np.int32)),
                jnp.asarray(attrs[s:e], jnp.float32),
                self.config.metric, self.index.qstats)

    def _maintain_paged(self, force: Optional[str]) -> Optional[str]:
        idx = self.index
        mcfg = self.monitor.cfg
        action = force
        if action is None:
            counts = np.asarray(idx.counts)
            nonempty = counts[counts > 0]
            mean_size = float(nonempty.mean()) if nonempty.size else 0.0
            growth = mean_size / max(idx.base_mean_size, 1.0) - 1.0
            if growth >= mcfg.growth_rebuild_threshold:
                action = "rebuild"
            elif int(idx.delta.count) >= \
                    mcfg.delta_flush_fraction * idx.delta.capacity:
                action = "flush"
        if action == "flush":
            self._paged_flush()
            return "flush"
        if action == "rebuild":
            # full re-cluster straight from the durable tier (pending rows
            # included); _attach_paged re-sizes the pool and drops every
            # frame, which IS the rebuild's cache invalidation
            n_rows = self.store.count()
            self._build_paged()
            row_b = 4 * self.store.dim + 4 + 4 * self.store.n_attr + 1 \
                + (self.store.dim if self.config.quantize == "int8" else 0)
            self.maintenance_log.append(maintenance.MaintenanceStats(
                kind="full", rows_moved=n_rows,
                partitions_touched=self.index.k,
                # a paged rebuild rewrites every row's partition id, its
                # codes, and the centroid generation -- same flash-wear
                # accounting as the resident full_rebuild
                bytes_written=n_rows * row_b
                + self.index.k * self.store.dim * 4,
                p_max_before=idx.cache.p_max,
                p_max_after=self.index.cache.p_max))
            return "rebuild"
        return None

    def _paged_flush(self, max_rows: Optional[int] = None):
        """Incremental paged flush: move live delta rows into their nearest
        partitions *durably* (the clustered SQLite table is the scan tier
        here, so unlike resident flush the partition ids must move on
        disk), write their codes, update centroids by the running-mean
        rule, and invalidate the touched partitions' frames. `max_rows`
        bounds the work quantum: the rest stays searchable in the delta.
        Returns the MaintenanceStats of the flush (None if no live rows)."""
        idx = self.index
        d = idx.delta
        quantized = idx.quantized
        live = np.nonzero(np.asarray(d.valid))[0]
        deferred = np.zeros((0,), np.int64)
        if max_rows is not None and live.size > max_rows:
            live, deferred = live[:max_rows], live[max_rows:]
        p_before = idx.cache.p_max
        stats = None
        if live.size:
            dx = np.asarray(d.vectors)[live]          # metric-normalised
            dids = np.asarray(d.ids)[live]
            assign = maintenance.assign_nearest_centroid(dx, idx.centroids)
            touched = np.unique(assign)
            if quantized:
                # move the insert-time codes verbatim (same contract as
                # resident flush_delta); re-encode only as a fallback
                dcod = (np.asarray(d.codes)[live] if d.codes is not None
                        else quantize.encode_np(idx.qstats, dx))
                self.store.set_code_tier(
                    dids, dcod, *quantize.stats_to_arrays(idx.qstats))
            idx.cache.invalidate(touched)
            idx.counts = idx.counts + np.bincount(assign, minlength=idx.k)
            cent = np.array(idx.centroids)
            csz = np.array(idx.csizes)
            if idx.drift is None:
                idx.drift = np.zeros((idx.k,), np.float32)
            maintenance.running_mean_update(cent, csz, dx, assign, touched,
                                            drift=idx.drift)
            idx.centroids = jnp.asarray(cent)
            idx.csizes = jnp.asarray(csz)
            # row moves + TOUCHED centroid rewrites in one transaction --
            # durable I/O matches the stats accounting (never O(k))
            self.store.apply_repair(dids, assign, touched,
                                    cent[touched], csz[touched])
            self._persist_maintenance_state()
            pad = effective_pad_to(self.config)
            new_p_max = int(idx.counts.max())
            new_p_max = max(idx.cache.p_max, -(-new_p_max // pad) * pad)
            if new_p_max > idx.cache.p_max:   # a partition outgrew a frame
                idx.cache.resize(new_p_max)
            stats = maintenance.MaintenanceStats(
                kind="incremental", rows_moved=int(live.size),
                partitions_touched=int(len(touched)),
                bytes_written=int(live.size
                                  * (4 * idx.dim + 4 + 4 * idx.n_attr + 1
                                     + (idx.dim if quantized else 0))
                                  + len(touched) * idx.dim * 4),
                p_max_before=p_before, p_max_after=idx.cache.p_max)
            self.maintenance_log.append(stats)
        # partial flush: deferred live rows compact to the front of a
        # fresh delta (the same compaction the resident path uses)
        idx.delta = maintenance.compact_delta(d, deferred, idx.n_attr,
                                              quantized, idx.qstats)
        return stats

    # -- helpers --------------------------------------------------------------
    def _refresh_stats(self):
        idx = self.index
        flat_attrs = np.asarray(idx.attrs).reshape(
            idx.k * idx.p_max, idx.n_attr)
        live = np.asarray(idx.valid).reshape(-1)
        self.optimizer = HybridOptimizer(AttributeStats(flat_attrs[live]))

    def _persist_maintenance_state(self):
        """Mirror the monitor's maintenance signals (per-partition drift
        accumulators + the rebuild baseline mean size) into the store's
        meta table, so recover() resumes maintenance timing instead of
        resetting drift to zero. Called at every point that durably
        changes the clustering or the signals themselves."""
        idx = self.index
        if idx is None:
            return
        drift = np.asarray(idx.drift, np.float32) if idx.drift is not None \
            else np.zeros((idx.k,), np.float32)
        self.store.set_maintenance_state(float(idx.base_mean_size), drift)

    def _persist_codes(self):
        """Mirror the resident code tier (+ quantizer stats) durably --
        one transaction, so codes and stats can never diverge -- letting
        recover() restore the tier without re-encoding."""
        idx = self.index
        if idx is None or idx.codes is None:
            return
        val = np.asarray(idx.valid)
        self.store.set_code_tier(np.asarray(idx.ids)[val],
                                 np.asarray(idx.codes)[val],
                                 *quantize.stats_to_arrays(idx.qstats))

    def _current_assignment(self) -> np.ndarray:
        """asset id -> partition id for every live main-tier row, as one
        numpy scatter from the packed ids/valid arrays (no per-partition
        host round-trips)."""
        idx = self.index
        vid = np.asarray(idx.ids)
        val = np.asarray(idx.valid)
        out = np.full(int(vid.max()) + 1 if vid.size else 1, -1, np.int64)
        rows = vid[val]
        parts = np.broadcast_to(
            np.arange(idx.k, dtype=np.int64)[:, None], vid.shape)[val]
        out[rows] = parts
        return out

    def _centroid_state(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray(self.index.centroids),
                np.asarray(self.index.csizes))
