"""MicroNN: the embeddable engine facade (paper Fig. 1).

Ties together the durable SQLite tier, the device-resident IVF index, the
index monitor, and the hybrid query optimizer -- the public API an
application links against:

    eng = MicroNN(dim=128, n_attr=2)
    eng.upsert(ids, vecs, attrs)
    eng.build()                      # initial clustering
    res = eng.search(q, k=100, n_probe=8)
    res = eng.search(q, k=10, predicate=Pred(0, "eq", 3.0))
    eng.delete(ids)
    eng.maintain()                   # flush delta / rebuild as needed

Writes are serialised (single writer, paper §3.6); every write lands in
SQLite (durable, WAL) *and* in the device index (delta-store), so readers
see updates immediately while the host copy guarantees recoverability --
`MicroNN.recover()` rebuilds device state from SQLite after a crash.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import delta as delta_ops
from ..core import executor, ivf, maintenance, quantize
from ..core.hybrid import AttributeStats, Node, compile_filter
from ..core.monitor import IndexMonitor, MonitorConfig
from ..core.optimizer import HybridOptimizer
from ..core.types import (DeltaStore, IVFConfig, IVFIndex, SearchResult,
                          normalize_if_cosine)
from .store import VectorStore


class MicroNN:
    def __init__(self, dim: int, n_attr: int = 0, path: str = ":memory:",
                 config: Optional[IVFConfig] = None,
                 monitor: Optional[MonitorConfig] = None,
                 quantize: Optional[str] = None,
                 rerank_factor: Optional[int] = None):
        """`quantize="int8"` turns on the scalar-quantized tier: searches
        scan int8 codes and rerank `rerank_factor * k` candidates at
        float32. Both knobs land in IVFConfig (explicit kwargs override a
        passed config); codes are durable in the SQLite `codes` table."""
        self.store = VectorStore(path, dim=dim, n_attr=n_attr)
        cfg = config or IVFConfig(dim=dim)
        if quantize is not None:
            cfg = dataclasses.replace(cfg, quantize=quantize)
        if rerank_factor is not None:
            cfg = dataclasses.replace(cfg, rerank_factor=rerank_factor)
        self.config = cfg
        self.monitor = IndexMonitor(monitor)
        self.index: Optional[IVFIndex] = None
        self.optimizer: Optional[HybridOptimizer] = None
        self.maintenance_log = []

    # -- lifecycle -----------------------------------------------------------
    def build(self):
        """Initial clustering from the durable tier (mini-batch k-means
        streams from SQLite -- never the full dataset in memory). With
        quantize="int8" the build also trains the quantizer from the
        store's rows (build_index trains min/max on the same data, so no
        second pass over SQLite) and persists codes + stats durably
        *before* the clustering swap: after a crash at any point the
        codes table is always decode-consistent with the stored qstats.
        """
        ids, _, vecs = self.store.all_rows()
        attrs = self.store.attributes_for(ids)
        self.index = ivf.build_index(
            vecs, ids.astype(np.int32), attrs, cfg=self.config)
        self._persist_codes()
        # persist the clustering back to the clustered table
        assign = self._current_assignment()
        self.store.set_partitions(ids, assign[ids], *self._centroid_state())
        self._refresh_stats()

    def recover(self):
        """Rebuild device state from SQLite after a crash/restart."""
        ids, parts, vecs = self.store.all_rows()
        attrs = self.store.attributes_for(ids)
        cents, csizes = self.store.centroids()
        if len(cents) == 0:
            # No durable clustering: drop *all* derived state. A stale
            # index/optimizer pair from a previous build must not keep
            # answering (hybrid) queries for a store that no longer backs
            # them.
            self.index = None
            self.optimizer = None
            return
        live = parts >= 0
        # the durable tier stores raw rows; the packed device index (and
        # the code tier) hold metric-normalised ones -- normalise the
        # main-tier rows before packing so recovery reproduces exactly
        # what build() put on device. Pending delta rows stay raw here:
        # the replay upsert below normalises them itself, exactly once,
        # like the live engine's write path did.
        vecs_live = np.asarray(normalize_if_cosine(
            jnp.asarray(vecs[live], jnp.float32), self.config.metric))
        qstats = None
        codes_live = None
        if self.config.quantize == "int8":
            qs = self.store.qstats()
            if qs is not None:
                # codes were persisted at build/upsert time: restore them
                # without re-encoding (the durable tier is authoritative);
                # rows missing a durable code (e.g. written by a pre-
                # quantization engine) are re-encoded from float32
                qstats = quantize.stats_from_arrays(*qs)
                codes_live, found = self.store.codes_for(ids[live])
                if not found.all():
                    codes_live[~found] = quantize.encode_np(
                        qstats, vecs_live[~found])
        packed = ivf.pack_partitions(
            vecs_live, ids[live].astype(np.int32), attrs[live],
            parts[live].astype(np.int64), len(cents),
            pad_to=self.config.pad_to, codes=codes_live)
        vec, vid, vat, val, counts, cod = packed
        idx = IVFIndex(
            centroids=jnp.asarray(cents), csizes=jnp.asarray(csizes),
            vectors=jnp.asarray(vec), ids=jnp.asarray(vid),
            attrs=jnp.asarray(vat), valid=jnp.asarray(val),
            counts=jnp.asarray(counts),
            delta=DeltaStore.empty(self.config.delta_capacity, self.store.dim,
                                   attrs.shape[1],
                                   quantized=cod is not None),
            base_mean_size=jnp.asarray(max(counts.mean(), 1.0), jnp.float32),
            codes=None if cod is None else jnp.asarray(cod),
            qstats=qstats,
            config=self.config)
        self.index = idx
        # replay delta rows (partition -1); upsert re-encodes them into
        # the delta's code block from the same stats, deterministically.
        # Replay in capacity-sized chunks with a flush in between -- the
        # store may hold more pending rows than the delta can seat (the
        # delta scatter would silently drop the overflow otherwise).
        if (~live).any():
            rv = vecs[~live]
            ri = ids[~live].astype(np.int32)
            ra = attrs[~live]
            cap = self.config.delta_capacity
            for s in range(0, len(rv), cap):
                e = min(s + cap, len(rv))
                if delta_ops.delta_free_slots(self.index) < e - s:
                    self.maintain(force="flush")
                self.index = delta_ops.upsert(
                    self.index, jnp.asarray(rv[s:e]), jnp.asarray(ri[s:e]),
                    jnp.asarray(ra[s:e]))
        self._refresh_stats()

    # -- writes ---------------------------------------------------------------
    def upsert(self, ids: np.ndarray, vecs: np.ndarray,
               attrs: Optional[np.ndarray] = None):
        n_attr = self.store.n_attr
        attrs = np.zeros((len(ids), n_attr), np.float32) if attrs is None \
            else attrs
        self.store.upsert(ids, vecs, attrs, partition_id=-1)
        if self.index is None:
            return
        if delta_ops.delta_free_slots(self.index) < len(ids):
            self.maintain(force="flush")
        self.index = delta_ops.upsert(
            self.index, jnp.asarray(vecs, jnp.float32),
            jnp.asarray(ids, jnp.int32), jnp.asarray(attrs, jnp.float32))
        # NB: no durable code write here -- pending (partition -1) rows are
        # replayed through delta_ops.upsert on recover(), which re-encodes
        # them deterministically; their durable codes are first written by
        # the next build()/rebuild's _persist_codes.

    def delete(self, ids: np.ndarray):
        self.store.delete(ids)
        if self.index is not None:
            self.index = delta_ops.delete(self.index,
                                          jnp.asarray(ids, jnp.int32))

    # -- maintenance ----------------------------------------------------------
    def maintain(self, force: Optional[str] = None) -> Optional[str]:
        if self.index is None:
            return None
        health = self.monitor.check(self.index)
        action = force or health.action
        if action == "flush":
            self.index, stats = maintenance.flush_delta(self.index)
            self.maintenance_log.append(stats)
            self.store.update_centroids(np.asarray(self.index.centroids),
                                        np.asarray(self.index.csizes))
            return "flush"
        if action == "rebuild":
            self.index, stats = maintenance.full_rebuild(self.index)
            self.maintenance_log.append(stats)
            # a rebuild retrains the quantizer -> every code changes;
            # persist codes+stats before the clustering swap (same crash
            # ordering as build())
            self._persist_codes()
            ids, _, _ = self.store.all_rows()
            assign = self._current_assignment()
            self.store.set_partitions(
                ids, assign[ids], *self._centroid_state())
            self._refresh_stats()
            return "rebuild"
        return None

    # -- queries --------------------------------------------------------------
    def search(self, queries: np.ndarray, k: int = 100, n_probe: int = 8,
               predicate: Optional[Node] = None, exact: bool = False,
               batch_mqo: Optional[bool] = None,
               backend: Optional[str] = None) -> SearchResult:
        """Every path compiles to a QueryPlan run by core/executor.py's
        fused scan; the executor's query-count bucketing means a stream of
        variable-size batches compiles once per bucket, not per call.
        `batch_mqo` is kept for API compatibility -- a batched ANN plan
        *is* the MQO shared scan (same union + selection mask)."""
        assert self.index is not None, "build() or recover() first"
        del batch_mqo
        q = jnp.asarray(np.atleast_2d(queries), jnp.float32)
        if predicate is not None:
            res, _ = self.optimizer.execute(
                self.index, q, predicate, k, n_probe, backend=backend)
            return res
        if exact:
            return executor.search(self.index, q, k=k, kind="exact",
                                   backend=backend)
        return executor.search(self.index, q, k=k, kind="ann",
                               n_probe=n_probe, backend=backend)

    # -- helpers --------------------------------------------------------------
    def _refresh_stats(self):
        idx = self.index
        flat_attrs = np.asarray(idx.attrs).reshape(
            idx.k * idx.p_max, idx.n_attr)
        live = np.asarray(idx.valid).reshape(-1)
        self.optimizer = HybridOptimizer(AttributeStats(flat_attrs[live]))

    def _persist_codes(self):
        """Mirror the resident code tier (+ quantizer stats) durably --
        one transaction, so codes and stats can never diverge -- letting
        recover() restore the tier without re-encoding."""
        idx = self.index
        if idx is None or idx.codes is None:
            return
        val = np.asarray(idx.valid)
        self.store.set_code_tier(np.asarray(idx.ids)[val],
                                 np.asarray(idx.codes)[val],
                                 *quantize.stats_to_arrays(idx.qstats))

    def _current_assignment(self) -> np.ndarray:
        """asset id -> partition id for every live main-tier row, as one
        numpy scatter from the packed ids/valid arrays (no per-partition
        host round-trips)."""
        idx = self.index
        vid = np.asarray(idx.ids)
        val = np.asarray(idx.valid)
        out = np.full(int(vid.max()) + 1 if vid.size else 1, -1, np.int64)
        rows = vid[val]
        parts = np.broadcast_to(
            np.arange(idx.k, dtype=np.int64)[:, None], vid.shape)[val]
        out[rows] = parts
        return out

    def _centroid_state(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray(self.index.centroids),
                np.asarray(self.index.csizes))
