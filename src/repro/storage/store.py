"""SQLite-backed durable vector store -- the paper's physical storage tier
(§3.2), verbatim where it matters:

  * WAL journal mode -> ACID upserts/deletes, single writer + concurrent
    snapshot readers (paper §3.6);
  * `vectors` is a WITHOUT ROWID table with PRIMARY KEY
    (partition_id, asset_id) -> a *clustered* index: rows are physically
    ordered by partition id, so a partition scan is sequential I/O;
  * centroids and attributes live in side tables (paper Fig. 2);
  * the delta-store is partition id -1 (the paper's "reserved partition
    identifier");
  * index rebuilds write a new *generation* and swap atomically -- readers
    keep a consistent view during maintenance (paper: "index rebuilds ...
    concurrently with transactionally consistent reads").

On a TPU pod this layer runs host-side: the durable home of the index,
the source for HBM uploads, and the substrate for checkpoint/restart.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import sqlite3
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

# SQLite bound-parameter ceiling (999 before 3.32); chunk IN (...) queries.
_PARAM_CHUNK = 500


@dataclasses.dataclass
class PartitionBlocks:
    """One batched probe-set fetch, packed as padded partition frames.

    Arrays are aligned to the requested pid order: frame j holds partition
    pids[j]. `vecs` rows are the *raw* durable vectors (the pager applies
    metric normalisation); `code_ok` marks rows whose int8 code existed in
    the durable side table (False rows are re-encoded by the caller).
    """

    vecs: Optional[np.ndarray]          # [m, p_max, d] f32 (None if skipped)
    ids: np.ndarray                     # [m, p_max] int32 (-1 padding)
    valid: np.ndarray                   # [m, p_max] bool
    codes: Optional[np.ndarray] = None  # [m, p_max, d] int8
    code_ok: Optional[np.ndarray] = None  # [m, p_max] bool
    attrs: Optional[np.ndarray] = None  # [m, p_max, n_attr] float32


class VectorStore:
    def __init__(self, path: str = ":memory:", dim: int = 128,
                 n_attr: int = 0):
        self.path = path
        self.dim = dim
        self.n_attr = n_attr
        # autocommit connection: transaction boundaries are owned by
        # transaction() below, which NESTS -- a write session wraps many
        # store calls in one outer BEGIN...COMMIT (paper §3.6's batched
        # single-writer commit), while standalone calls still get their
        # own transaction. check_same_thread=False lets the background
        # maintenance scheduler and the pager's locked fault path use the
        # connection from worker threads; callers must serialise access
        # (PartitionCache holds an RLock around every store call, and the
        # engine's write path is single-writer by contract).
        self.db = sqlite3.connect(path, isolation_level=None,
                                  check_same_thread=False)
        self.db.execute("PRAGMA journal_mode=WAL")
        self.db.execute("PRAGMA synchronous=NORMAL")
        self._txn_depth = 0
        self._create()
        # Snapshot read connection (PR 7, file-backed stores only): the
        # query path's reads -- pager faults, rerank gathers, attribute
        # gathers -- go through a SECOND connection so WAL gives them
        # snapshot isolation: a reader never observes another thread's
        # open write transaction mid-flight, only committed states (and
        # every committed prefix is servable by the crash-ordering
        # contract: codes land before row moves). Writes, and any read
        # that must see the surrounding transaction, stay on `self.db`.
        # An in-memory database is private to its connection, so
        # `:memory:` stores keep single-connection semantics -- callers
        # needing concurrent readers (the serving front door checks
        # `snapshot_reads`) should use a file path.
        self._rdb: Optional[sqlite3.Connection] = None
        if path != ":memory:":
            self._rdb = sqlite3.connect(path, isolation_level=None,
                                        check_same_thread=False)

    @property
    def snapshot_reads(self) -> bool:
        """True when reads run on a dedicated WAL snapshot connection
        (file-backed store) -- the precondition for serving queries
        concurrently with writers without engine-level serialization."""
        return self._rdb is not None

    @property
    def read_db(self) -> sqlite3.Connection:
        """Connection for query-path reads: the WAL snapshot connection
        when available, else the write connection."""
        return self._rdb if self._rdb is not None else self.db

    @contextlib.contextmanager
    def transaction(self):
        """Nestable transaction scope: only the outermost level issues
        BEGIN/COMMIT (ROLLBACK on any exception), so engine-level batch
        operations -- MicroNN.session() commits above all -- can compose
        store primitives into one atomic durable write."""
        if self._txn_depth == 0:
            self.db.execute("BEGIN IMMEDIATE")
        self._txn_depth += 1
        try:
            yield
        except BaseException:
            self._txn_depth -= 1
            if self._txn_depth == 0:
                self.db.execute("ROLLBACK")
            raise
        else:
            self._txn_depth -= 1
            if self._txn_depth == 0:
                try:
                    self.db.execute("COMMIT")
                except BaseException:
                    # a failed COMMIT (disk full, ...) leaves the SQLite
                    # transaction open: roll it back so the connection is
                    # not wedged for every later transaction() scope
                    try:
                        self.db.execute("ROLLBACK")
                    except sqlite3.Error:
                        pass
                    raise

    # -- schema -------------------------------------------------------------
    def _create(self):
        attr_cols = ", ".join(f"a{i} REAL DEFAULT 0" for i in range(self.n_attr))
        attr_cols = (", " + attr_cols) if attr_cols else ""
        with self.transaction():
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS vectors ("
                " partition_id INTEGER NOT NULL,"
                " asset_id INTEGER NOT NULL,"
                " vec BLOB NOT NULL,"
                " PRIMARY KEY (partition_id, asset_id)) WITHOUT ROWID")
            self.db.execute(
                "CREATE UNIQUE INDEX IF NOT EXISTS vectors_by_asset"
                " ON vectors(asset_id)")
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS centroids ("
                " generation INTEGER NOT NULL,"
                " partition_id INTEGER NOT NULL,"
                " vec BLOB NOT NULL, csize REAL DEFAULT 0,"
                " PRIMARY KEY (generation, partition_id)) WITHOUT ROWID")
            self.db.execute(
                f"CREATE TABLE IF NOT EXISTS attributes ("
                f" asset_id INTEGER PRIMARY KEY{attr_cols})")
            # int8 SQ code tier (paper's low-memory resident scan): codes
            # are durable alongside the float32 vectors so recover() can
            # restore the quantized index without re-encoding; quantizer
            # stats live in `meta` under "qstats".
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS codes ("
                " asset_id INTEGER PRIMARY KEY, code BLOB NOT NULL)")
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS meta (k TEXT PRIMARY KEY, v TEXT)")
            if self._meta("generation") is None:
                self._set_meta("generation", "0")

    def _meta(self, k: str) -> Optional[str]:
        row = self.db.execute("SELECT v FROM meta WHERE k=?", (k,)).fetchone()
        return row[0] if row else None

    def _set_meta(self, k: str, v: str):
        self.db.execute(
            "INSERT INTO meta(k, v) VALUES (?, ?)"
            " ON CONFLICT(k) DO UPDATE SET v=excluded.v", (k, v))

    @property
    def generation(self) -> int:
        return int(self._meta("generation") or 0)

    # -- writes (single writer; each call is one transaction) ---------------
    def upsert(self, asset_ids: Sequence[int], vecs: np.ndarray,
               attrs: Optional[np.ndarray] = None, partition_id: int = -1):
        """Upsert into the given partition (-1 = delta-store)."""
        vecs = np.ascontiguousarray(vecs, np.float32)
        with self.transaction():
            self.db.executemany(
                "DELETE FROM vectors WHERE asset_id=?",
                [(int(a),) for a in asset_ids])
            self.db.executemany(
                "INSERT INTO vectors(partition_id, asset_id, vec)"
                " VALUES (?, ?, ?)",
                [(partition_id, int(a), v.tobytes())
                 for a, v in zip(asset_ids, vecs)])
            if attrs is not None and self.n_attr:
                cols = ", ".join(f"a{i}" for i in range(self.n_attr))
                ph = ", ".join("?" * (self.n_attr + 1))
                self.db.executemany(
                    f"INSERT OR REPLACE INTO attributes(asset_id, {cols})"
                    f" VALUES ({ph})",
                    [(int(a), *map(float, row))
                     for a, row in zip(asset_ids, attrs)])

    def delete(self, asset_ids: Sequence[int]):
        with self.transaction():
            self.db.executemany("DELETE FROM vectors WHERE asset_id=?",
                                [(int(a),) for a in asset_ids])
            self.db.executemany("DELETE FROM attributes WHERE asset_id=?",
                                [(int(a),) for a in asset_ids])
            self.db.executemany("DELETE FROM codes WHERE asset_id=?",
                                [(int(a),) for a in asset_ids])

    def _gather_by_asset(self, cols: str, table: str,
                         asset_ids: Sequence[int]):
        """Shared scaffolding for every batched asset-id gather: dedup the
        wanted ids, chunk the IN (...) under the bound-parameter limit,
        and yield (row, output_index) -- duplicates in `asset_ids` map to
        every requesting position."""
        pos: dict = {}
        for j, a in enumerate(asset_ids):
            pos.setdefault(int(a), []).append(j)
        want = list(pos)
        for s in range(0, len(want), _PARAM_CHUNK):
            chunk = want[s:s + _PARAM_CHUNK]
            ph = ", ".join("?" * len(chunk))
            for row in self.read_db.execute(
                    f"SELECT asset_id, {cols} FROM {table}"
                    f" WHERE asset_id IN ({ph})", chunk):
                for j in pos[row[0]]:
                    yield row, j

    # -- quantized tier ------------------------------------------------------
    def codes_for(self, asset_ids: Sequence[int]
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """([n, d] int8 codes, [n] found mask) for the given assets; the
        caller decides how to fill rows with no durable code (the engine
        re-encodes them from the float32 tier)."""
        out = np.zeros((len(asset_ids), self.dim), np.int8)
        found = np.zeros((len(asset_ids),), bool)
        for (_, blob), j in self._gather_by_asset("code", "codes",
                                                  asset_ids):
            out[j] = np.frombuffer(blob, np.int8)
            found[j] = True
        return out, found

    def set_code_tier(self, asset_ids: Sequence[int], codes: np.ndarray,
                      lo: np.ndarray, scale: np.ndarray):
        """Atomically persist codes + quantizer stats in one transaction:
        a crash never leaves codes decodable with the wrong stats."""
        self.set_code_tier_streaming(iter([(asset_ids, codes)]), lo, scale)

    def set_code_tier_streaming(self, chunks, lo: np.ndarray,
                                scale: np.ndarray):
        """set_code_tier over a stream of (asset_ids, codes) chunks, all
        inside ONE transaction -- the paged build encodes batch-by-batch
        without losing the codes-consistent-with-stats crash guarantee."""
        with self.transaction():
            for asset_ids, codes in chunks:
                codes = np.ascontiguousarray(codes, np.int8)
                self.db.executemany(
                    "INSERT OR REPLACE INTO codes(asset_id, code)"
                    " VALUES (?, ?)",
                    [(int(a), c.tobytes())
                     for a, c in zip(asset_ids, codes)])
            self._set_meta("qstats", json.dumps(
                {"lo": [float(x) for x in lo],
                 "scale": [float(x) for x in scale]}))

    def qstats(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        raw = self._meta("qstats")
        if raw is None:
            return None
        d = json.loads(raw)
        return (np.asarray(d["lo"], np.float32),
                np.asarray(d["scale"], np.float32))

    # -- maintenance state ---------------------------------------------------
    def set_maintenance_state(self, base_mean_size: float,
                              drift: np.ndarray):
        """Persist the monitor's maintenance signals (per-partition
        accumulated centroid drift + the rebuild baseline mean size) so a
        recovered index resumes maintenance where the crashed process left
        off, instead of resetting drift to zero and mis-timing the next
        local repair."""
        with self.transaction():
            self._set_meta("maintenance", json.dumps(
                {"base_mean_size": float(base_mean_size),
                 "drift": [float(x) for x in np.asarray(drift)]}))

    def maintenance_state(self) -> Optional[Tuple[float, np.ndarray]]:
        raw = self._meta("maintenance")
        if raw is None:
            return None
        d = json.loads(raw)
        return (float(d["base_mean_size"]),
                np.asarray(d["drift"], np.float32))

    def set_partitions(self, asset_ids: np.ndarray, partition_ids: np.ndarray,
                       centroids: np.ndarray, csizes: np.ndarray):
        """Atomically install a new clustering generation (paper: the
        partition IDs in the vector table are updated after (re)clustering).
        The clustered PK physically re-orders rows by partition."""
        gen = self.generation + 1
        with self.transaction():
            rows = self.db.execute(
                "SELECT asset_id, vec FROM vectors").fetchall()
            by_id = {a: v for a, v in rows}
            self.db.execute("DELETE FROM vectors")
            self.db.executemany(
                "INSERT INTO vectors(partition_id, asset_id, vec)"
                " VALUES (?, ?, ?)",
                [(int(p), int(a), by_id[int(a)])
                 for a, p in zip(asset_ids, partition_ids)])
            self.db.executemany(
                "INSERT INTO centroids(generation, partition_id, vec, csize)"
                " VALUES (?, ?, ?, ?)",
                [(gen, i, np.ascontiguousarray(c, np.float32).tobytes(),
                  float(s))
                 for i, (c, s) in enumerate(zip(centroids, csizes))])
            self.db.execute("DELETE FROM centroids WHERE generation < ?",
                            (gen,))
            self._set_meta("generation", str(gen))

    def reassign_partitions(self, asset_ids: Sequence[int],
                            partition_ids: Sequence[int],
                            centroids: np.ndarray, csizes: np.ndarray):
        """Install a new clustering generation WITHOUT materialising the
        vector blobs (the paged build's swap): partition ids move via
        keyed UPDATEs against the clustered PK (SQLite re-inserts the row
        at its new key, preserving the physical clustering), centroids
        swap generations atomically. Same contract as set_partitions but
        O(1) vector bytes in host memory."""
        gen = self.generation + 1
        with self.transaction():
            self.db.executemany(
                "UPDATE vectors SET partition_id=? WHERE asset_id=?",
                [(int(p), int(a))
                 for a, p in zip(asset_ids, partition_ids)])
            self.db.executemany(
                "INSERT INTO centroids(generation, partition_id, vec, csize)"
                " VALUES (?, ?, ?, ?)",
                [(gen, i, np.ascontiguousarray(c, np.float32).tobytes(),
                  float(s))
                 for i, (c, s) in enumerate(zip(centroids, csizes))])
            self.db.execute("DELETE FROM centroids WHERE generation < ?",
                            (gen,))
            self._set_meta("generation", str(gen))

    def iter_asset_ids(self):
        """All asset ids in the clustered scan order (the same order
        iter_batches streams the vectors)."""
        return np.array([r[0] for r in self.db.execute(
            "SELECT asset_id FROM vectors"
            " ORDER BY partition_id, asset_id")], np.int64)

    def move_to_partition(self, asset_ids: Sequence[int],
                          partition_ids: Sequence[int]):
        """Incremental maintenance: move rows between partitions (delta
        flush, split/merge row reassignment). A keyed UPDATE against the
        clustered (partition_id, asset_id) primary key re-inserts each row
        at its new key -- one executemany instead of a SELECT/DELETE/
        INSERT round-trip per row; absent asset ids are no-ops."""
        with self.transaction():
            self.db.executemany(
                "UPDATE vectors SET partition_id=? WHERE asset_id=?",
                [(int(p), int(a))
                 for a, p in zip(asset_ids, partition_ids)])

    def apply_repair(self, moved_ids: Sequence[int],
                     moved_pids: Sequence[int],
                     touched_pids: Sequence[int],
                     centroids: np.ndarray, csizes: np.ndarray):
        """Persist one local repair (split/merge/recluster) atomically:
        the moved rows' keyed partition UPDATEs and the *touched*
        partitions' centroid rows commit in ONE transaction at the
        current generation -- a crash serves the pre-repair clustering
        bit-identically, and write I/O scales with the touched
        neighbourhood, never the collection (the full generation swap
        stays the rebuild path's mechanism). `centroids`/`csizes` are
        the touched partitions' new states, aligned to `touched_pids`
        (a split's appended slot is simply a new partition_id row)."""
        gen = self.generation
        with self.transaction():
            self.db.executemany(
                "UPDATE vectors SET partition_id=? WHERE asset_id=?",
                [(int(p), int(a))
                 for a, p in zip(moved_ids, moved_pids)])
            self.db.executemany(
                "INSERT OR REPLACE INTO centroids"
                " (generation, partition_id, vec, csize) VALUES (?, ?, ?, ?)",
                [(gen, int(p),
                  np.ascontiguousarray(c, np.float32).tobytes(), float(s))
                 for p, c, s in zip(touched_pids, centroids, csizes)])

    def update_centroids(self, centroids: np.ndarray, csizes: np.ndarray):
        gen = self.generation
        with self.transaction():
            self.db.executemany(
                "INSERT OR REPLACE INTO centroids"
                " (generation, partition_id, vec, csize) VALUES (?, ?, ?, ?)",
                [(gen, i, np.ascontiguousarray(c, np.float32).tobytes(),
                  float(s))
                 for i, (c, s) in enumerate(zip(centroids, csizes))])

    # -- reads (snapshot-consistent within one connection txn) --------------
    def count(self) -> int:
        return self.read_db.execute(
            "SELECT COUNT(*) FROM vectors").fetchone()[0]

    def scan_partition(self, pid: int) -> Tuple[np.ndarray, np.ndarray]:
        rows = self.read_db.execute(
            "SELECT asset_id, vec FROM vectors WHERE partition_id=?"
            " ORDER BY asset_id", (pid,)).fetchall()
        if not rows:
            return (np.zeros((0,), np.int64),
                    np.zeros((0, self.dim), np.float32))
        ids = np.fromiter((r[0] for r in rows), np.int64, count=len(rows))
        # one decode of the concatenated blobs instead of a per-row loop
        vecs = np.frombuffer(b"".join(r[1] for r in rows), np.float32) \
            .reshape(len(rows), self.dim).copy()
        return ids, vecs

    def scan_partitions(self, pids: Sequence[int], p_max: int,
                        with_codes: bool = False,
                        with_attrs: bool = False,
                        with_vecs: bool = True) -> PartitionBlocks:
        """Batched probe-set fetch (the pager's fault path): every listed
        partition in one SQL round-trip (chunked only by the bound-
        parameter limit), packed into padded [m, p_max, *] frame blocks.
        The clustered (partition_id, asset_id) primary key makes each
        partition a sequential range scan; codes and attributes ride along
        via LEFT JOINs so a frame fault is a single pass over the rows.
        `with_vecs=False` skips reading the float32 blobs entirely -- an
        int8 frame fault then moves 4x fewer bytes off disk, which is the
        point of the code tier (the rare code-less row is backfilled by
        the caller via vectors_for).

        Packing is vectorized (one blob join + bulk scatter per column
        rather than per-row numpy calls): the Python-side cost of a fault
        is a few list comprehensions, so nearly all of the fetch is
        C-level SQLite + numpy work that releases the GIL -- which is
        what lets the pager's read-ahead stage() actually overlap a
        concurrent scan instead of fighting it for the interpreter."""
        m = len(pids)
        want = [int(p) for p in pids]
        assert len(set(want)) == m, "duplicate partition ids in one fetch"
        vecs = np.zeros((m, p_max, self.dim), np.float32) if with_vecs \
            else None
        ids = np.full((m, p_max), -1, np.int32)
        valid = np.zeros((m, p_max), bool)
        codes = np.zeros((m, p_max, self.dim), np.int8) if with_codes else None
        code_ok = np.zeros((m, p_max), bool) if with_codes else None
        n_attr = self.n_attr if with_attrs else 0
        attrs = np.zeros((m, p_max, n_attr), np.float32) if with_attrs \
            else None
        cols = "v.partition_id, v.asset_id"
        if with_vecs:
            cols += ", v.vec"
        joins = ""
        if with_codes:
            cols += ", c.code"
            joins += " LEFT JOIN codes c ON c.asset_id = v.asset_id"
        if with_attrs and self.n_attr:
            cols += ", " + ", ".join(f"a.a{i}" for i in range(self.n_attr))
            joins += " LEFT JOIN attributes a ON a.asset_id = v.asset_id"
        for s in range(0, m, _PARAM_CHUNK):
            chunk = want[s:s + _PARAM_CHUNK]
            ph = ", ".join("?" * len(chunk))
            rows = self.read_db.execute(
                f"SELECT {cols} FROM vectors v{joins}"
                f" WHERE v.partition_id IN ({ph})"
                f" ORDER BY v.partition_id, v.asset_id", chunk).fetchall()
            if not rows:
                continue
            nr = len(rows)
            pid_col = np.fromiter((r[0] for r in rows), np.int64, nr)
            # pid -> block row: slot of chunk[t] is s + t, recovered by a
            # searchsorted over the sorted chunk (no per-row dict lookups)
            sidx = np.argsort(np.asarray(chunk, np.int64), kind="stable")
            j_col = (s + sidx)[np.searchsorted(
                np.asarray(chunk, np.int64)[sidx], pid_col)]
            # slot within the partition: rows arrive grouped by pid (the
            # ORDER BY), so it is the offset from each group's start
            starts = np.flatnonzero(
                np.r_[True, pid_col[1:] != pid_col[:-1]])
            counts = np.diff(np.r_[starts, nr])
            if counts.max() > p_max:
                big = pid_col[starts[np.argmax(counts)]]
                raise ValueError(
                    f"partition {big} overflows frame p_max={p_max}")
            i_col = np.arange(nr) - np.repeat(starts, counts)
            ids[j_col, i_col] = np.fromiter(
                (r[1] for r in rows), np.int64, nr)
            valid[j_col, i_col] = True
            c = 2
            if with_vecs:
                vecs[j_col, i_col] = np.frombuffer(
                    b"".join(r[c] for r in rows),
                    np.float32).reshape(nr, self.dim)
                c += 1
            if with_codes:
                blobs = [r[c] for r in rows]
                ok = np.fromiter((b is not None for b in blobs), bool, nr)
                sel = np.flatnonzero(ok)
                if len(sel):
                    codes[j_col[sel], i_col[sel]] = np.frombuffer(
                        b"".join(blobs[t] for t in sel),
                        np.int8).reshape(len(sel), self.dim)
                    code_ok[j_col[sel], i_col[sel]] = True
                c += 1
            if with_attrs and self.n_attr:
                arows = [r[c:c + self.n_attr] for r in rows]
                sel = np.flatnonzero(np.fromiter(
                    (a[0] is not None for a in arows), bool, nr))
                if len(sel):
                    attrs[j_col[sel], i_col[sel]] = np.asarray(
                        [arows[t] for t in sel], np.float32)
        return PartitionBlocks(vecs=vecs, ids=ids, valid=valid, codes=codes,
                               code_ok=code_ok, attrs=attrs)

    def vectors_for(self, asset_ids: Sequence[int]
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """([n, d] f32 raw vectors, [n] found mask) for the given assets in
        one batched IN (...) query -- the paged rerank's disk gather."""
        out = np.zeros((len(asset_ids), self.dim), np.float32)
        found = np.zeros((len(asset_ids),), bool)
        for (_, blob), j in self._gather_by_asset("vec", "vectors",
                                                  asset_ids):
            out[j] = np.frombuffer(blob, np.float32)
            found[j] = True
        return out, found

    def partitions_for(self, asset_ids: Sequence[int]) -> np.ndarray:
        """asset id -> current partition id (-2 where the asset is absent;
        -1 is the delta partition). Batched IN (...) lookup."""
        out = np.full((len(asset_ids),), -2, np.int64)
        for (_, p), j in self._gather_by_asset("partition_id", "vectors",
                                               asset_ids):
            out[j] = p
        return out

    def partition_counts(self, k: int) -> np.ndarray:
        """[k] live main-tier rows per partition (one GROUP BY scan)."""
        out = np.zeros((k,), np.int64)
        for p, c in self.read_db.execute(
                "SELECT partition_id, COUNT(*) FROM vectors"
                " WHERE partition_id >= 0 GROUP BY partition_id"):
            if 0 <= p < k:
                out[p] = c
        return out

    def centroids(self) -> Tuple[np.ndarray, np.ndarray]:
        rows = self.read_db.execute(
            "SELECT vec, csize FROM centroids WHERE generation=?"
            " ORDER BY partition_id", (self.generation,)).fetchall()
        if not rows:
            return np.zeros((0, self.dim), np.float32), np.zeros((0,))
        return (np.stack([np.frombuffer(r[0], np.float32) for r in rows]),
                np.array([r[1] for r in rows], np.float32))

    def iter_batches(self, batch_size: int) -> Iterator[np.ndarray]:
        """Stream all vectors partition-ordered (clustered scan)."""
        cur = self.db.execute(
            "SELECT vec FROM vectors ORDER BY partition_id, asset_id")
        while True:
            rows = cur.fetchmany(batch_size)
            if not rows:
                return
            yield np.stack([np.frombuffer(r[0], np.float32) for r in rows])

    def sample(self, size: int, rng: np.random.Generator) -> np.ndarray:
        """Uniform random row sample (mini-batch k-means feed)."""
        n = self.count()
        if n == 0:
            return np.zeros((0, self.dim), np.float32)
        idx = sorted(int(i) for i in rng.integers(0, n, size=size))
        out = []
        cur = self.db.execute(
            "SELECT vec FROM vectors ORDER BY partition_id, asset_id")
        want = iter(idx)
        nxt = next(want, None)
        for i, row in enumerate(cur):
            while nxt is not None and nxt == i:
                out.append(np.frombuffer(row[0], np.float32))
                nxt = next(want, None)
            if nxt is None:
                break
        return np.stack(out) if out else np.zeros((0, self.dim), np.float32)

    def all_rows(self):
        rows = self.db.execute(
            "SELECT asset_id, partition_id, vec FROM vectors"
            " ORDER BY partition_id, asset_id").fetchall()
        ids = np.array([r[0] for r in rows], np.int64)
        parts = np.array([r[1] for r in rows], np.int64)
        vecs = np.stack([np.frombuffer(r[2], np.float32) for r in rows]) \
            if rows else np.zeros((0, self.dim), np.float32)
        return ids, parts, vecs

    def attributes_for(self, asset_ids: np.ndarray) -> np.ndarray:
        """Batched attribute gather: one IN (...) query per parameter
        chunk instead of a fetchone round-trip per asset id."""
        if not self.n_attr:
            return np.zeros((len(asset_ids), 0), np.float32)
        cols = ", ".join(f"a{i}" for i in range(self.n_attr))
        out = np.zeros((len(asset_ids), self.n_attr), np.float32)
        for row, j in self._gather_by_asset(cols, "attributes", asset_ids):
            out[j] = row[1:]
        return out

    def close(self):
        if self._rdb is not None:
            self._rdb.close()
        self.db.close()
