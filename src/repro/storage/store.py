"""SQLite-backed durable vector store -- the paper's physical storage tier
(§3.2), verbatim where it matters:

  * WAL journal mode -> ACID upserts/deletes, single writer + concurrent
    snapshot readers (paper §3.6);
  * `vectors` is a WITHOUT ROWID table with PRIMARY KEY
    (partition_id, asset_id) -> a *clustered* index: rows are physically
    ordered by partition id, so a partition scan is sequential I/O;
  * centroids and attributes live in side tables (paper Fig. 2);
  * the delta-store is partition id -1 (the paper's "reserved partition
    identifier");
  * index rebuilds write a new *generation* and swap atomically -- readers
    keep a consistent view during maintenance (paper: "index rebuilds ...
    concurrently with transactionally consistent reads").

On a TPU pod this layer runs host-side: the durable home of the index,
the source for HBM uploads, and the substrate for checkpoint/restart.
"""
from __future__ import annotations

import json
import os
import sqlite3
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np


class VectorStore:
    def __init__(self, path: str = ":memory:", dim: int = 128,
                 n_attr: int = 0):
        self.path = path
        self.dim = dim
        self.n_attr = n_attr
        self.db = sqlite3.connect(path)
        self.db.execute("PRAGMA journal_mode=WAL")
        self.db.execute("PRAGMA synchronous=NORMAL")
        self._create()

    # -- schema -------------------------------------------------------------
    def _create(self):
        attr_cols = ", ".join(f"a{i} REAL DEFAULT 0" for i in range(self.n_attr))
        attr_cols = (", " + attr_cols) if attr_cols else ""
        with self.db:
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS vectors ("
                " partition_id INTEGER NOT NULL,"
                " asset_id INTEGER NOT NULL,"
                " vec BLOB NOT NULL,"
                " PRIMARY KEY (partition_id, asset_id)) WITHOUT ROWID")
            self.db.execute(
                "CREATE UNIQUE INDEX IF NOT EXISTS vectors_by_asset"
                " ON vectors(asset_id)")
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS centroids ("
                " generation INTEGER NOT NULL,"
                " partition_id INTEGER NOT NULL,"
                " vec BLOB NOT NULL, csize REAL DEFAULT 0,"
                " PRIMARY KEY (generation, partition_id)) WITHOUT ROWID")
            self.db.execute(
                f"CREATE TABLE IF NOT EXISTS attributes ("
                f" asset_id INTEGER PRIMARY KEY{attr_cols})")
            # int8 SQ code tier (paper's low-memory resident scan): codes
            # are durable alongside the float32 vectors so recover() can
            # restore the quantized index without re-encoding; quantizer
            # stats live in `meta` under "qstats".
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS codes ("
                " asset_id INTEGER PRIMARY KEY, code BLOB NOT NULL)")
            self.db.execute(
                "CREATE TABLE IF NOT EXISTS meta (k TEXT PRIMARY KEY, v TEXT)")
            if self._meta("generation") is None:
                self._set_meta("generation", "0")

    def _meta(self, k: str) -> Optional[str]:
        row = self.db.execute("SELECT v FROM meta WHERE k=?", (k,)).fetchone()
        return row[0] if row else None

    def _set_meta(self, k: str, v: str):
        self.db.execute(
            "INSERT INTO meta(k, v) VALUES (?, ?)"
            " ON CONFLICT(k) DO UPDATE SET v=excluded.v", (k, v))

    @property
    def generation(self) -> int:
        return int(self._meta("generation") or 0)

    # -- writes (single writer; each call is one transaction) ---------------
    def upsert(self, asset_ids: Sequence[int], vecs: np.ndarray,
               attrs: Optional[np.ndarray] = None, partition_id: int = -1):
        """Upsert into the given partition (-1 = delta-store)."""
        vecs = np.ascontiguousarray(vecs, np.float32)
        with self.db:
            self.db.executemany(
                "DELETE FROM vectors WHERE asset_id=?",
                [(int(a),) for a in asset_ids])
            self.db.executemany(
                "INSERT INTO vectors(partition_id, asset_id, vec)"
                " VALUES (?, ?, ?)",
                [(partition_id, int(a), v.tobytes())
                 for a, v in zip(asset_ids, vecs)])
            if attrs is not None and self.n_attr:
                cols = ", ".join(f"a{i}" for i in range(self.n_attr))
                ph = ", ".join("?" * (self.n_attr + 1))
                self.db.executemany(
                    f"INSERT OR REPLACE INTO attributes(asset_id, {cols})"
                    f" VALUES ({ph})",
                    [(int(a), *map(float, row))
                     for a, row in zip(asset_ids, attrs)])

    def delete(self, asset_ids: Sequence[int]):
        with self.db:
            self.db.executemany("DELETE FROM vectors WHERE asset_id=?",
                                [(int(a),) for a in asset_ids])
            self.db.executemany("DELETE FROM attributes WHERE asset_id=?",
                                [(int(a),) for a in asset_ids])
            self.db.executemany("DELETE FROM codes WHERE asset_id=?",
                                [(int(a),) for a in asset_ids])

    # -- quantized tier ------------------------------------------------------
    def codes_for(self, asset_ids: Sequence[int]
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """([n, d] int8 codes, [n] found mask) for the given assets; the
        caller decides how to fill rows with no durable code (the engine
        re-encodes them from the float32 tier)."""
        out = np.zeros((len(asset_ids), self.dim), np.int8)
        found = np.zeros((len(asset_ids),), bool)
        pos = {int(a): j for j, a in enumerate(asset_ids)}
        want = list(pos)
        chunk = 500  # stay under SQLite's bound-parameter limit
        for s in range(0, len(want), chunk):
            ph = ", ".join("?" * len(want[s:s + chunk]))
            for a, blob in self.db.execute(
                    f"SELECT asset_id, code FROM codes"
                    f" WHERE asset_id IN ({ph})", want[s:s + chunk]):
                j = pos[a]
                out[j] = np.frombuffer(blob, np.int8)
                found[j] = True
        return out, found

    def set_code_tier(self, asset_ids: Sequence[int], codes: np.ndarray,
                      lo: np.ndarray, scale: np.ndarray):
        """Atomically persist codes + quantizer stats in one transaction:
        a crash never leaves codes decodable with the wrong stats."""
        codes = np.ascontiguousarray(codes, np.int8)
        with self.db:
            self.db.executemany(
                "INSERT OR REPLACE INTO codes(asset_id, code) VALUES (?, ?)",
                [(int(a), c.tobytes()) for a, c in zip(asset_ids, codes)])
            self._set_meta("qstats", json.dumps(
                {"lo": [float(x) for x in lo],
                 "scale": [float(x) for x in scale]}))

    def qstats(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        raw = self._meta("qstats")
        if raw is None:
            return None
        d = json.loads(raw)
        return (np.asarray(d["lo"], np.float32),
                np.asarray(d["scale"], np.float32))

    def set_partitions(self, asset_ids: np.ndarray, partition_ids: np.ndarray,
                       centroids: np.ndarray, csizes: np.ndarray):
        """Atomically install a new clustering generation (paper: the
        partition IDs in the vector table are updated after (re)clustering).
        The clustered PK physically re-orders rows by partition."""
        gen = self.generation + 1
        with self.db:
            rows = self.db.execute(
                "SELECT asset_id, vec FROM vectors").fetchall()
            by_id = {a: v for a, v in rows}
            self.db.execute("DELETE FROM vectors")
            self.db.executemany(
                "INSERT INTO vectors(partition_id, asset_id, vec)"
                " VALUES (?, ?, ?)",
                [(int(p), int(a), by_id[int(a)])
                 for a, p in zip(asset_ids, partition_ids)])
            self.db.executemany(
                "INSERT INTO centroids(generation, partition_id, vec, csize)"
                " VALUES (?, ?, ?, ?)",
                [(gen, i, np.ascontiguousarray(c, np.float32).tobytes(),
                  float(s))
                 for i, (c, s) in enumerate(zip(centroids, csizes))])
            self.db.execute("DELETE FROM centroids WHERE generation < ?",
                            (gen,))
            self._set_meta("generation", str(gen))

    def move_to_partition(self, asset_ids: Sequence[int],
                          partition_ids: Sequence[int]):
        """Incremental maintenance: move delta rows into IVF partitions."""
        with self.db:
            rows = [(int(p), int(a)) for a, p in zip(asset_ids, partition_ids)]
            for p, a in rows:
                vec = self.db.execute(
                    "SELECT vec FROM vectors WHERE asset_id=?", (a,)
                ).fetchone()
                if vec is None:
                    continue
                self.db.execute("DELETE FROM vectors WHERE asset_id=?", (a,))
                self.db.execute(
                    "INSERT INTO vectors(partition_id, asset_id, vec)"
                    " VALUES (?, ?, ?)", (p, a, vec[0]))

    def update_centroids(self, centroids: np.ndarray, csizes: np.ndarray):
        gen = self.generation
        with self.db:
            self.db.executemany(
                "INSERT OR REPLACE INTO centroids"
                " (generation, partition_id, vec, csize) VALUES (?, ?, ?, ?)",
                [(gen, i, np.ascontiguousarray(c, np.float32).tobytes(),
                  float(s))
                 for i, (c, s) in enumerate(zip(centroids, csizes))])

    # -- reads (snapshot-consistent within one connection txn) --------------
    def count(self) -> int:
        return self.db.execute("SELECT COUNT(*) FROM vectors").fetchone()[0]

    def scan_partition(self, pid: int) -> Tuple[np.ndarray, np.ndarray]:
        rows = self.db.execute(
            "SELECT asset_id, vec FROM vectors WHERE partition_id=?"
            " ORDER BY asset_id", (pid,)).fetchall()
        if not rows:
            return (np.zeros((0,), np.int64),
                    np.zeros((0, self.dim), np.float32))
        ids = np.array([r[0] for r in rows], np.int64)
        vecs = np.stack([np.frombuffer(r[1], np.float32) for r in rows])
        return ids, vecs

    def centroids(self) -> Tuple[np.ndarray, np.ndarray]:
        rows = self.db.execute(
            "SELECT vec, csize FROM centroids WHERE generation=?"
            " ORDER BY partition_id", (self.generation,)).fetchall()
        if not rows:
            return np.zeros((0, self.dim), np.float32), np.zeros((0,))
        return (np.stack([np.frombuffer(r[0], np.float32) for r in rows]),
                np.array([r[1] for r in rows], np.float32))

    def iter_batches(self, batch_size: int) -> Iterator[np.ndarray]:
        """Stream all vectors partition-ordered (clustered scan)."""
        cur = self.db.execute(
            "SELECT vec FROM vectors ORDER BY partition_id, asset_id")
        while True:
            rows = cur.fetchmany(batch_size)
            if not rows:
                return
            yield np.stack([np.frombuffer(r[0], np.float32) for r in rows])

    def sample(self, size: int, rng: np.random.Generator) -> np.ndarray:
        """Uniform random row sample (mini-batch k-means feed)."""
        n = self.count()
        if n == 0:
            return np.zeros((0, self.dim), np.float32)
        idx = sorted(int(i) for i in rng.integers(0, n, size=size))
        out = []
        cur = self.db.execute(
            "SELECT vec FROM vectors ORDER BY partition_id, asset_id")
        want = iter(idx)
        nxt = next(want, None)
        for i, row in enumerate(cur):
            while nxt is not None and nxt == i:
                out.append(np.frombuffer(row[0], np.float32))
                nxt = next(want, None)
            if nxt is None:
                break
        return np.stack(out) if out else np.zeros((0, self.dim), np.float32)

    def all_rows(self):
        rows = self.db.execute(
            "SELECT asset_id, partition_id, vec FROM vectors"
            " ORDER BY partition_id, asset_id").fetchall()
        ids = np.array([r[0] for r in rows], np.int64)
        parts = np.array([r[1] for r in rows], np.int64)
        vecs = np.stack([np.frombuffer(r[2], np.float32) for r in rows]) \
            if rows else np.zeros((0, self.dim), np.float32)
        return ids, parts, vecs

    def attributes_for(self, asset_ids: np.ndarray) -> np.ndarray:
        if not self.n_attr:
            return np.zeros((len(asset_ids), 0), np.float32)
        cols = ", ".join(f"a{i}" for i in range(self.n_attr))
        out = np.zeros((len(asset_ids), self.n_attr), np.float32)
        for j, a in enumerate(asset_ids):
            row = self.db.execute(
                f"SELECT {cols} FROM attributes WHERE asset_id=?",
                (int(a),)).fetchone()
            if row:
                out[j] = row
        return out

    def close(self):
        self.db.close()
