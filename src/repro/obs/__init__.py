"""Observability: unified metrics registry + per-query tracing (PR 8).

Two pieces, one import surface:

  * `metrics` -- named counters/gauges/histograms in one process
    registry; every subsystem (pager, executor, front door, scheduler,
    engine) registers into `default_registry()` under labeled scopes so
    `MicroNN.stats()` is a derived view of a single source of truth.
  * `trace` -- thread-local per-query spans (`QueryTrace`), the bounded
    `TraceRing` of recent traces + maintenance events, and the
    slow-query log.
"""
from . import metrics, trace
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, Scope,
                      default_registry, next_instance)
from .trace import (MaintEvent, QueryTrace, Span, TraceRing, activate,
                    current, enabled, set_enabled)

__all__ = [
    "metrics", "trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Scope",
    "default_registry", "next_instance",
    "MaintEvent", "QueryTrace", "Span", "TraceRing",
    "activate", "current", "enabled", "set_enabled",
]
