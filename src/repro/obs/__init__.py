"""Observability: unified metrics registry + per-query tracing (PR 8).

Two pieces, one import surface:

  * `metrics` -- named counters/gauges/histograms in one process
    registry; every subsystem (pager, executor, front door, scheduler,
    engine) registers into `default_registry()` under labeled scopes so
    `MicroNN.stats()` is a derived view of a single source of truth.
  * `trace` -- thread-local per-query spans (`QueryTrace`), the bounded
    `TraceRing` of recent traces + maintenance events, and the
    slow-query log.
  * `recorder` -- the workload flight recorder (PR 10): bounded,
    sampled on-disk capture of (ts_offset, tenant, spec, vectors) and
    the deterministic `replay()` harness asserting bit-identical
    ResultSets.
  * `http` -- the live exposition endpoint (PR 10): stdlib HTTP daemon
    thread serving /metrics, /healthz, /traces, /slow, /events.
"""
from . import http, metrics, recorder, trace
from .http import ExpositionServer
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, Scope,
                      default_registry, next_instance)
from .recorder import FlightRecorder, ReplayReport, recording, replay
from .trace import (MaintEvent, QueryTrace, Span, TraceRing, activate,
                    current, enabled, set_enabled)

__all__ = [
    "metrics", "trace", "recorder", "http",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Scope",
    "default_registry", "next_instance",
    "MaintEvent", "QueryTrace", "Span", "TraceRing",
    "activate", "current", "enabled", "set_enabled",
    "FlightRecorder", "ReplayReport", "recording", "replay",
    "ExpositionServer",
]
