"""Per-query trace spans + the maintenance event log (PR 8).

`MicroNN.query(vecs, spec, trace=True)` (or `MicroNN.explain(vecs,
spec)`) activates a thread-local QueryTrace for the duration of that one
query; every layer the query flows through -- engine planner, executor
probe/scan/rerank/merge, pager fault path -- checks `trace.current()`
and, when a trace is active, records a named Span carrying wall time and
work counters:

    plan          spec resolution (hybrid pre/post choice), kind, k
    probe         centroid probe: partitions in the probe union, n_probe
    pager_fault   paged only: frames hit/missed/staged-consumed, bytes
                  read from SQLite, accumulated over every chunk fault
    scan          the fused scan: partitions, rows, chunks, backend,
                  Q-bucket, jit compile count (cache hit <=> compiled=0)
    rerank        quantized only: candidates, rows gathered (fused=1 on
                  the resident path, where rerank lives inside the one
                  jitted call)
    merge         delta-merge epilogue (fused=1 resident)
    queue_wait /  front-door requests only: admission latency and the
    split         coalesced-batch sub-span (callers, batch rows)

Tracing-off cost: `current()` is one module-bool test plus one
thread-local dict lookup (~100 ns); NO span objects, dicts, or registry
entries are allocated when no trace is active -- pinned by the bench_obs
overhead gate (<= 3% on a ~150 us query) and the zero-allocation test.
`set_enabled(False)` is the global kill-switch that makes every hook a
no-op even under an activated trace; it doubles as the baseline arm of
the overhead benchmark.

The engine owns a TraceRing: a bounded ring of the last N QueryTraces
plus the maintenance event log -- structured MaintEvents the scheduler
emits (work item planned, quantum executed, no-op plans, daemon errors)
-- and a slow-query log capturing traces above a latency threshold, so
a sustained-churn run is explainable after the fact.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

# -- canonical stage names (tests assert against these) ---------------------
STAGE_PLAN = "plan"
STAGE_PROBE = "probe"
STAGE_FAULT = "pager_fault"
STAGE_SCAN = "scan"
STAGE_RERANK = "rerank"
STAGE_MERGE = "merge"
STAGE_QUEUE = "queue_wait"
STAGE_SPLIT = "split"

# global kill-switch: False turns every hook into a no-op regardless of
# activated traces (the overhead benchmark's baseline arm)
_ENABLED = True

_tls = threading.local()


def set_enabled(flag: bool):
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


def current() -> Optional["QueryTrace"]:
    """The thread's active QueryTrace, or None (the hot-path check:
    one bool test + one dict lookup, no allocation)."""
    if not _ENABLED:
        return None
    return _tls.__dict__.get("active")


@contextlib.contextmanager
def activate(trace: "QueryTrace"):
    """Install `trace` as the thread's active trace for the block."""
    d = _tls.__dict__
    prev = d.get("active")
    d["active"] = trace
    try:
        yield trace
    finally:
        d["active"] = prev


@dataclasses.dataclass
class Span:
    """One named stage of a query: accumulated wall time + counters.
    Repeated record() calls with the same name ACCUMULATE (the paged
    fault span sums over every chunk fault): dur_ms and numeric counters
    add, string counters keep the latest value, `calls` counts the
    recordings."""

    name: str
    dur_ms: float = 0.0
    calls: int = 0
    counters: Dict[str, object] = dataclasses.field(default_factory=dict)

    def add(self, dur_ms: float, counters: Dict[str, object]):
        self.dur_ms += dur_ms
        self.calls += 1
        for k, v in counters.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                self.counters[k] = v
            else:
                self.counters[k] = self.counters.get(k, 0) + v

    def to_dict(self) -> Dict:
        return {"name": self.name, "dur_ms": self.dur_ms,
                "calls": self.calls, "counters": dict(self.counters)}


class QueryTrace:
    """The per-query record: ordered stage spans + identity fields.

    Created by MicroNN.query(trace=True) / explain() / the front door's
    traced submit; layers record into it through trace.current(). The
    front door additionally builds one per-caller trace per coalesced
    request that ADOPTS the shared fused-call spans and adds its own
    queue_wait/split sub-spans."""

    __slots__ = ("mode", "spec", "n_queries", "spans", "total_ms", "ts",
                 "result", "shared", "_t0")

    def __init__(self, mode: str = "resident", spec=None,
                 n_queries: int = 0):
        self.mode = mode            # "resident" | "paged"
        self.spec = spec            # resolved QuerySpec (set by the engine)
        self.n_queries = n_queries
        self.spans: Dict[str, Span] = {}    # insertion-ordered
        self.total_ms = 0.0
        self.ts = time.time()
        self.result = None          # ResultSet (explain() attaches it)
        self.shared = None          # fused-call trace (coalesced requests)
        self._t0 = time.perf_counter()

    # -- recording ----------------------------------------------------------
    def record(self, name: str, dur_ms: float = 0.0, **counters):
        span = self.spans.get(name)
        if span is None:
            span = Span(name)
            self.spans[name] = span
        span.add(dur_ms, counters)

    @contextlib.contextmanager
    def span(self, name: str, **counters):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.record(name, (time.perf_counter() - t0) * 1e3, **counters)

    def finish(self):
        self.total_ms = (time.perf_counter() - self._t0) * 1e3
        return self

    def adopt(self, other: "QueryTrace"):
        """Reference another trace's spans (the front door's per-caller
        traces adopt the shared fused-call spans -- no copying; the
        shared Span objects are read-only after the call completes)."""
        for name, span in other.spans.items():
            self.spans.setdefault(name, span)
        if self.spec is None:
            self.spec = other.spec
        self.shared = other

    # -- views --------------------------------------------------------------
    def get(self, name: str) -> Optional[Span]:
        return self.spans.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self.spans

    @property
    def span_names(self) -> Tuple[str, ...]:
        return tuple(self.spans)

    def counter(self, span: str, key: str, default=0):
        s = self.spans.get(span)
        return default if s is None else s.counters.get(key, default)

    def to_dict(self) -> Dict:
        return {"mode": self.mode, "n_queries": self.n_queries,
                "total_ms": self.total_ms, "ts": self.ts,
                "spec": None if self.spec is None else repr(self.spec),
                "spans": [s.to_dict() for s in self.spans.values()]}

    def format(self) -> str:
        """Human-readable per-stage breakdown (what explain() prints)."""
        head = (f"QueryTrace mode={self.mode} q={self.n_queries} "
                f"total={self.total_ms:.2f}ms")
        if self.spec is not None:
            head += f"\n  spec: {self.spec!r}"
        rows = []
        for s in self.spans.values():
            kv = " ".join(f"{k}={v}" for k, v in s.counters.items())
            calls = f" x{s.calls}" if s.calls > 1 else ""
            rows.append(f"  {s.name:<12}{s.dur_ms:>9.3f}ms{calls}  {kv}")
        return "\n".join([head] + rows)

    def __repr__(self) -> str:
        return (f"QueryTrace(mode={self.mode!r}, q={self.n_queries}, "
                f"total_ms={self.total_ms:.2f}, "
                f"spans={list(self.spans)})")


@dataclasses.dataclass
class MaintEvent:
    """One structured maintenance event (the scheduler's event log):
    kind is "planned" (work item selected), "step" (quantum executed),
    "noop" (item planned to nothing and was skipped), or "daemon_error"
    (the daemon swallowed an exception)."""

    kind: str
    action: str = ""
    pids: Tuple[int, ...] = ()
    rows: int = 0
    bytes_written: int = 0
    dur_ms: float = 0.0
    error: str = ""
    daemon: bool = False
    ts: float = dataclasses.field(default_factory=time.time)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class TraceRing:
    """Bounded ring of the last N records -- QueryTraces and MaintEvents
    share it (one timeline: a slow query next to the repair that caused
    it) -- plus the slow-query log: traces whose total_ms exceeded the
    threshold are ALSO kept in a separate small ring, so a latency spike
    survives long after the main ring has rotated past it."""

    def __init__(self, capacity: int = 256, slow_ms: float = 100.0,
                 slow_capacity: int = 64):
        assert capacity >= 1, capacity
        self.slow_ms = float(slow_ms)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=int(capacity))
        self._slow: deque = deque(maxlen=int(slow_capacity))

    def append(self, rec):
        with self._lock:
            self._ring.append(rec)
            if isinstance(rec, QueryTrace) and rec.total_ms >= self.slow_ms:
                self._slow.append(rec)

    def records(self, n: Optional[int] = None) -> List:
        with self._lock:
            out = list(self._ring)
        return out if n is None else out[-n:]

    def traces(self, n: Optional[int] = None) -> List[QueryTrace]:
        out = [r for r in self.records() if isinstance(r, QueryTrace)]
        return out if n is None else out[-n:]

    def events(self, n: Optional[int] = None) -> List[MaintEvent]:
        out = [r for r in self.records() if isinstance(r, MaintEvent)]
        return out if n is None else out[-n:]

    def slow(self) -> List[QueryTrace]:
        with self._lock:
            return list(self._slow)

    def clear(self):
        with self._lock:
            self._ring.clear()
            self._slow.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)
