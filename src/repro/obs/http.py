"""Live exposition endpoint (PR 10): the obs layer over plain HTTP.

One stdlib `http.server` on a daemon thread (no framework, nothing to
install on-device) serving the observability surfaces that already
exist in-process:

    /metrics   MetricsRegistry.to_prometheus()  (text/plain; scrapable)
    /healthz   the bound health callable's JSON (Fleet.health() or
               MicroNN.stats()); 200 always -- the VERDICTS carry the
               degradation signal, the endpoint itself only fails if
               the process is gone
    /traces    the TraceRing's QueryTraces as JSON
    /slow      the slow-query log as JSON
    /events    the maintenance MaintEvents as JSON

Non-perturbation contract (gated by tests/test_flight.py): every data
source is lock-free or takes only its own short internal lock --
registry metric locks, the TraceRing deque lock -- NEVER the engine
write mutex and never the fleet lock while engines are held, so a
scrape cannot stall queries, writers, or the maintenance daemon, and a
concurrent scrape provably leaves query results bit-identical.

The server binds 127.0.0.1 by default (observability is not an API
gateway; bind a routable host explicitly if you mean it) and port=0
picks an ephemeral port (`server.port` after start()).

    srv = ExpositionServer.for_target(fleet)   # or a MicroNN
    srv.start()
    requests.get(f"http://127.0.0.1:{srv.port}/healthz")
    srv.stop()
"""
from __future__ import annotations

import dataclasses
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from . import metrics as obs_metrics
from . import trace as obs_trace

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _jsonable(obj):
    """Best-effort JSON coercion for trace/event payloads (numpy
    scalars, dataclasses, tuples-as-keys never reach here; anything
    exotic degrades to repr instead of 500ing the scrape)."""
    try:
        json.dumps(obj)
        return obj
    except (TypeError, ValueError):
        if dataclasses.is_dataclass(obj):
            return _jsonable(dataclasses.asdict(obj))
        if isinstance(obj, dict):
            return {str(k): _jsonable(v) for k, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            return [_jsonable(v) for v in obj]
        if hasattr(obj, "item"):        # numpy scalar
            return obj.item()
        return repr(obj)


class ExpositionServer:
    """Daemon-thread HTTP server over a registry + health fn + ring."""

    def __init__(self, *, registry: Optional[
            obs_metrics.MetricsRegistry] = None,
            health: Optional[Callable[[], dict]] = None,
            ring: Optional[obs_trace.TraceRing] = None,
            host: str = "127.0.0.1", port: int = 0):
        self.registry = registry or obs_metrics.default_registry()
        self.health = health
        self.ring = ring
        self.host = host
        self._port_req = int(port)
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    @classmethod
    def for_target(cls, target, **kwargs) -> "ExpositionServer":
        """Wire the endpoint to a Fleet or a MicroNN by duck-typing:
        `health()` when the target has one (Fleet), else `stats()`;
        `traces` ring when present (engine)."""
        health = getattr(target, "health", None) or \
            getattr(target, "stats", None)
        ring = getattr(target, "traces", None)
        if not isinstance(ring, obs_trace.TraceRing):
            ring = None
        return cls(health=health, ring=ring, **kwargs)

    # -- lifecycle -----------------------------------------------------------
    @property
    def port(self) -> int:
        assert self._server is not None, "start() first"
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ExpositionServer":
        if self._server is not None:
            return self
        srv = self  # captured by the handler closure below

        class Handler(BaseHTTPRequestHandler):
            # observability must not spam stderr per scrape
            def log_message(self, fmt, *args):
                pass

            def do_GET(self):
                try:
                    path = self.path.split("?", 1)[0].rstrip("/") or "/"
                    if path == "/metrics":
                        body = srv.registry.to_prometheus().encode()
                        ctype = PROM_CONTENT_TYPE
                    elif path in ("/healthz", "/"):
                        doc = srv.health() if srv.health is not None \
                            else {"status": "ok"}
                        body = json.dumps(_jsonable(doc)).encode()
                        ctype = "application/json"
                    elif path in ("/traces", "/slow", "/events"):
                        ring = srv.ring
                        if ring is None:
                            items = []
                        elif path == "/traces":
                            items = [t.to_dict() for t in ring.traces()]
                        elif path == "/slow":
                            items = [t.to_dict() for t in ring.slow()]
                        else:
                            items = [e.to_dict() for e in ring.events()]
                        body = json.dumps(_jsonable(items)).encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404, "unknown path")
                        return
                except Exception as e:   # a scrape must never kill us
                    self.send_error(500, type(e).__name__)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

        self._server = ThreadingHTTPServer((self.host, self._port_req),
                                           Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            name="micronn-exposition", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        if self._server is None:
            return
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)
        self._server = None
        self._thread = None

    def __enter__(self) -> "ExpositionServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.stop()
        return False
