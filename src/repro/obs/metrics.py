"""Unified metrics registry (PR 8): ONE process-wide source of truth for
every component's operational counters.

Before this module each subsystem kept private ad-hoc telemetry -- plain
ints on the pager, a reservoir + percentile helper on the serving front
door, a bare queue-depth probe on the scheduler -- four disconnected
dicts with no export path. The registry replaces all of them with three
first-class metric kinds:

    Counter     monotonic (thread-safe add; settable only for counter
                carry-over across component rebuilds)
    Gauge       point-in-time value, either set explicitly or derived
                from a zero-arg callback at read time (e.g. the
                executor's live jit-cache size)
    Histogram   fixed log-spaced buckets, mergeable across instances,
                interpolated quantiles -- the shared replacement for the
                front door's private latency reservoirs

Metrics are keyed by (name, labels): `registry.counter("pager.hits",
engine="0")` is get-or-create, so a component re-created against the
same labels (a paged rebuild re-attaching its frame pool) keeps its
cumulative series. `scope(**labels)` returns a view that pre-binds
labels -- the engine hands each subsystem `engine.metrics.scope(
component="pager")` and every metric the subsystem registers lands in
the one default registry under that engine's labels.

Export: `snapshot()` is the JSON view (embedded into every BENCH_*.json
by benchmarks.common.write_json); `to_prometheus()` is the text
exposition format for scraping. `MicroNN.stats()` keys are now derived
views over this registry -- same keys as before, one source of truth.

Hot-path contract: reading a Counter/Gauge is lock-free; incrementing
takes the metric's own lock (a few hundred ns). Nothing here allocates
after registration -- the tracing-off overhead gate (bench_obs) holds
the whole obs layer under 3% on a ~150us query.
"""
from __future__ import annotations

import itertools
import re
import threading
from bisect import bisect_left, bisect_right
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

# Default histogram buckets: log-spaced upper edges covering 1us..~134s
# at a factor of sqrt(2) per bucket -- fine enough that an interpolated
# p50/p99 lands within ~20% of the exact sample, over the full range a
# query or maintenance quantum can take. Values are in the observed unit
# (the repo observes seconds); an overflow bucket catches the rest.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    1e-6 * (2.0 ** (i / 2.0)) for i in range(55))

_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


def _escape_label_value(v: str) -> str:
    """Prometheus text-format label escaping: backslash, double quote and
    newline must be escaped or a scraper's parser rejects the whole
    exposition (a tenant named `a"b` would poison /metrics)."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels_prom(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(
        f'{_SANITIZE.sub("_", k)}="{_escape_label_value(v)}"'
        for k, v in labels) + "}"


class Counter:
    """Monotonic counter. `set()` exists only so a rebuilt component can
    carry its cumulative series over (the pager across paged rebuilds);
    normal use is inc()."""

    kind = "counter"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1):
        with self._lock:
            self._value += n

    def set(self, value: int):
        with self._lock:
            self._value = int(value)

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self):
        return self._value


class Gauge:
    """Point-in-time value: set explicitly, or lazily computed by a
    zero-arg callback at read time (`fn`), e.g. executor.trace_count."""

    kind = "gauge"
    __slots__ = ("name", "labels", "_lock", "_value", "fn")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0
        self.fn = fn

    def set(self, value: float):
        with self._lock:
            self._value = value

    @property
    def value(self) -> float:
        if self.fn is not None:
            return self.fn()
        return self._value

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    `buckets` are ascending upper edges; counts[i] tallies observations
    <= buckets[i], with one extra overflow bucket past the last edge.
    Fixed edges make instances MERGEABLE (elementwise count addition) --
    the property the front door's per-instance reservoirs lacked -- and
    the exporter can emit cumulative Prometheus `le` series directly.
    `quantile(q)` interpolates linearly inside the winning bucket, so a
    p50/p99 over sqrt(2)-spaced edges lands within ~20% of the exact
    order statistic (plenty for gates bounded 100x above the signal)."""

    kind = "histogram"
    __slots__ = ("name", "labels", "_lock", "buckets", "counts", "_sum",
                 "_count", "_min", "_max")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self.buckets = tuple(buckets) if buckets is not None \
            else DEFAULT_BUCKETS
        assert all(a < b for a, b in zip(self.buckets, self.buckets[1:])), \
            "histogram buckets must be strictly ascending"
        self.counts = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float):
        i = bisect_left(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self._sum += v
            self._count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def merge(self, other: "Histogram"):
        """Fold another histogram (same bucket edges) into this one."""
        assert self.buckets == other.buckets, \
            "can only merge histograms with identical bucket edges"
        with self._lock:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self._sum += other._sum
            self._count += other._count
            self._min = min(self._min, other._min)
            self._max = max(self._max, other._max)

    def fraction_above(self, v: float) -> float:
        """Fraction of observations above `v`, at bucket resolution
        (an observation whose bucket straddles `v` counts as above --
        the conservative direction for an SLO violation estimate).
        0.0 when empty."""
        j = bisect_right(self.buckets, v)
        with self._lock:
            n = self._count
            if n == 0:
                return 0.0
            below = sum(self.counts[:j])
            return (n - below) / n

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (0 <= q <= 1); 0.0 when empty."""
        with self._lock:
            n = self._count
            if n == 0:
                return 0.0
            target = q * n
            cum = 0
            for i, c in enumerate(self.counts):
                if c == 0:
                    continue
                if cum + c >= target:
                    lo = self.buckets[i - 1] if i > 0 else min(
                        self._min, self.buckets[0])
                    hi = self.buckets[i] if i < len(self.buckets) \
                        else self._max
                    lo = max(lo, self._min)
                    hi = min(max(hi, lo), self._max)
                    frac = (target - cum) / c
                    return lo + frac * (hi - lo)
                cum += c
            return self._max

    def snapshot(self):
        with self._lock:
            nonzero = [[(self.buckets[i] if i < len(self.buckets)
                         else float("inf")), c]
                       for i, c in enumerate(self.counts) if c]
            return {"count": self._count, "sum": self._sum,
                    "min": self._min if self._count else 0.0,
                    "max": self._max if self._count else 0.0,
                    "p50": self.quantile_unlocked(0.50),
                    "p99": self.quantile_unlocked(0.99),
                    "buckets": nonzero}

    def quantile_unlocked(self, q: float) -> float:
        # snapshot() already holds the lock; RLock semantics by hand
        n = self._count
        if n == 0:
            return 0.0
        target = q * n
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else min(
                    self._min, self.buckets[0])
                hi = self.buckets[i] if i < len(self.buckets) else self._max
                lo = max(lo, self._min)
                hi = min(max(hi, lo), self._max)
                return lo + (target - cum) / c * (hi - lo)
            cum += c
        return self._max


class MetricsRegistry:
    """Thread-safe get-or-create registry keyed on (name, labels).

    Cardinality guard (PR 9): fleet mode labels series per TENANT, so
    an unbounded tenant population must not grow the registry without
    bound. Each metric NAME keeps an LRU over its label sets, capped at
    `max_series_per_name`; registering a fresh label set past the cap
    evicts the least-recently-REGISTERED/looked-up series for that name
    and increments the registry's own `obs_series_evicted` counter. An
    evicted series simply restarts from zero if its component comes
    back (get-or-create re-creates it) -- the same contract as a
    process restart. The LRU is touched only inside _get (component
    construction), never on inc()/observe(), so the hot-path
    zero-allocation guarantee is unchanged."""

    def __init__(self, max_series_per_name: int = 512):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}
        # name -> OrderedDict(label_key -> None), most recent LAST
        self._by_name: Dict[str, "OrderedDict"] = {}
        self.max_series_per_name = int(max_series_per_name)

    def _evicted_counter(self) -> Counter:
        # the guard's own telemetry, registered directly (self._lock is
        # NOT re-entrant) under its own name: a single-series name, so
        # it can never evict itself
        key = ("obs_series_evicted", ())
        m = self._metrics.get(key)
        if m is None:
            m = Counter(*key)
            self._metrics[key] = m
            self._by_name.setdefault("obs_series_evicted",
                                     OrderedDict())[()] = None
        return m

    def _get(self, cls, name: str, labels: Dict[str, str], **kwargs):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            lru = self._by_name.setdefault(name, OrderedDict())
            if m is None:
                while len(lru) >= max(self.max_series_per_name, 1):
                    old_labels, _ = lru.popitem(last=False)
                    del self._metrics[(name, old_labels)]
                    self._evicted_counter().inc()
                m = cls(name, key[1], **kwargs)
                self._metrics[key] = m
                lru[key[1]] = None
            else:
                assert isinstance(m, cls), \
                    f"metric {name!r}{labels} already registered as " \
                    f"{m.kind}, not {cls.kind}"
                lru.move_to_end(key[1])
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None,
              **labels) -> Gauge:
        g = self._get(Gauge, name, labels)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None,
                  **labels) -> Histogram:
        if buckets is not None:
            return self._get(Histogram, name, labels, buckets=buckets)
        return self._get(Histogram, name, labels)

    def scope(self, **labels) -> "Scope":
        return Scope(self, dict(labels))

    def size(self) -> int:
        """Number of registered metric series (the zero-allocation
        contract of the tracing-off hot path asserts this is stable)."""
        with self._lock:
            return len(self._metrics)

    def _sorted_items(self):
        with self._lock:
            items = list(self._metrics.items())
        return sorted(items, key=lambda kv: (kv[0][0], kv[0][1]))

    def snapshot(self) -> Dict:
        """JSON view: {"counters": {...}, "gauges": {...},
        "histograms": {...}} keyed 'name{k="v",...}'."""
        out: Dict[str, Dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for (name, labels), m in self._sorted_items():
            key = name + _fmt_labels(labels)
            out[m.kind + "s"][key] = m.snapshot()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (names sanitized: dots ->
        underscores; histograms emit cumulative `le` bucket series +
        _sum/_count). Label values are escaped per the text-format spec
        and each metric family gets exactly one `# HELP` + `# TYPE`
        header -- duplicated headers or a raw quote/newline in a label
        value make strict scrapers reject the whole page."""
        lines: List[str] = []
        seen_family: set = set()
        for (name, labels), m in self._sorted_items():
            pname = _SANITIZE.sub("_", name)
            if pname not in seen_family:
                seen_family.add(pname)
                # HELP text escapes only backslash + newline (spec);
                # metric names are dotted identifiers so this is belt
                # and braces
                help_txt = name.replace("\\", "\\\\").replace("\n", "\\n")
                lines.append(f"# HELP {pname} {help_txt} ({m.kind})")
                lines.append(f"# TYPE {pname} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for i, c in enumerate(m.counts):
                    cum += c
                    le = f"{m.buckets[i]:.9g}" if i < len(m.buckets) \
                        else "+Inf"
                    ls = _fmt_labels_prom(labels + (("le", le),))
                    lines.append(f"{pname}_bucket{ls} {cum}")
                ls = _fmt_labels_prom(labels)
                lines.append(f"{pname}_sum{ls} {m.sum:.9g}")
                lines.append(f"{pname}_count{ls} {m.count}")
            else:
                v = m.value
                vs = f"{v:.9g}" if isinstance(v, float) else str(v)
                lines.append(f"{pname}{_fmt_labels_prom(labels)} {vs}")
        return "\n".join(lines) + "\n"


class Scope:
    """A label-binding view over a registry: every metric created through
    the scope carries the scope's labels (nested scopes merge theirs).
    The engine hands one scope per component, so the whole process shares
    ONE registry yet each engine/component reads its own series."""

    __slots__ = ("registry", "labels")

    def __init__(self, registry: MetricsRegistry, labels: Dict[str, str]):
        self.registry = registry
        self.labels = labels

    def _merged(self, labels: Dict) -> Dict:
        merged = dict(self.labels)
        merged.update(labels)
        return merged

    def counter(self, name: str, **labels) -> Counter:
        return self.registry.counter(name, **self._merged(labels))

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None,
              **labels) -> Gauge:
        return self.registry.gauge(name, fn=fn, **self._merged(labels))

    def histogram(self, name: str,
                  buckets: Optional[Tuple[float, ...]] = None,
                  **labels) -> Histogram:
        return self.registry.histogram(name, buckets=buckets,
                                       **self._merged(labels))

    def scope(self, **labels) -> "Scope":
        return Scope(self.registry, self._merged(labels))


_DEFAULT = MetricsRegistry()
_INSTANCES = itertools.count()


def default_registry() -> MetricsRegistry:
    """THE process registry every component registers into."""
    return _DEFAULT


def next_instance() -> str:
    """Monotonic instance label for components constructed outside an
    engine scope (a bare PartitionCache in a test) -- keeps their series
    distinct without the caller inventing label plumbing."""
    return str(next(_INSTANCES))
