"""Workload flight recorder (PR 10): bounded, sampled, on-disk capture
of the live query stream -- and a deterministic replay harness that
turns any captured window into a runnable regression test.

The production question this answers (MicroNN's deployment setting is
thousands of on-device / per-tenant indexes): *a user hit a slow query
or a recall complaint an hour ago -- how do I reproduce it?* Metrics
(PR 8) say THAT it happened; traces say WHERE the time went for queries
still in the ring; neither can re-execute the workload. The recorder
captures, for a sampled subset of live traffic,

    (ts_offset, tenant, site, spec, query vectors[, result digest])

into a single SQLite file, and `replay()` re-executes any captured
window against an engine (or a whole `Fleet`) and asserts bit-identical
ResultSets: ids AND exact-f32 scores. Everything in the execution path
is deterministic for a fixed store state (jit-compiled fused scans,
order-stable top-k, bit-identical paged/resident + coalesced/solo
parity -- all individually gated), so capture-time digest == replay
digest is an end-to-end invariant, not a statistical hope; bench_obs
gates it per PR.

Hot-path contract (same as obs.trace): recording OFF must cost ONE
branch per hook site. Hooks read the module global directly --

    rec = recorder._ACTIVE
    if rec is not None: rec.record(...)

-- no function call, no allocation, nothing else. The <=3% overhead
gate in benchmarks/bench_obs.py measures this with the recorder
uninstalled, exactly like the tracing-off arm.

Capture sites (the `site` column tells replay what it is looking at):

    engine.query      MicroNN.query -- vectors + spec + result digest
    frontdoor.submit  FrontDoor.submit -- vectors + spec at admission
                      (no digest: the Future has not resolved; replay
                      self-checks these by double execution)
    fleet.get         Fleet.get -- tenant handle touch, no vectors;
                      replay uses these to reproduce open/spill order

Bounded: `max_records` caps the file (capture stops, drops counted);
`sample_every=N` keeps every Nth eligible call (deterministic -- the
same workload samples the same records). Records are buffered and
flushed to SQLite every `flush_every` appends, on `flush()`, and on
`close()` -- the recording hot path never waits on fsync.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import pickle
import sqlite3
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from . import metrics as obs_metrics

# sites ---------------------------------------------------------------------
SITE_ENGINE = "engine.query"
SITE_FRONTDOOR = "frontdoor.submit"
SITE_FLEET_GET = "fleet.get"

_SCHEMA = """
CREATE TABLE IF NOT EXISTS flight (
    seq       INTEGER PRIMARY KEY,
    ts_offset REAL NOT NULL,
    tenant    TEXT,
    site      TEXT NOT NULL,
    spec      BLOB,
    vecs      BLOB,
    q         INTEGER NOT NULL DEFAULT 0,
    dim       INTEGER NOT NULL DEFAULT 0,
    digest    TEXT
);
CREATE TABLE IF NOT EXISTS meta (k TEXT PRIMARY KEY, v TEXT);
"""

# THE process-global active recorder. Hook sites read this name
# directly (`recorder._ACTIVE`): recording-off is one global load +
# one `is not None` branch -- the same budget as obs.trace's
# kill-switch bool. Installed/removed only via install()/uninstall().
_ACTIVE: Optional["FlightRecorder"] = None
_INSTALL_LOCK = threading.Lock()


def active() -> Optional["FlightRecorder"]:
    return _ACTIVE


def install(rec: "FlightRecorder"):
    """Make `rec` the process recorder (at most one at a time)."""
    global _ACTIVE
    with _INSTALL_LOCK:
        assert _ACTIVE is None or _ACTIVE is rec, \
            "another FlightRecorder is already installed"
        _ACTIVE = rec


def uninstall(rec: Optional["FlightRecorder"] = None):
    global _ACTIVE
    with _INSTALL_LOCK:
        if rec is None or _ACTIVE is rec:
            _ACTIVE = None


def result_digest(res) -> str:
    """Bit-exact fingerprint of a ResultSet: sha256 over the shapes,
    dtypes and raw bytes of ids + scores. Two results digest equal iff
    every id and every float32 score is bit-identical."""
    ids, scores = res.to_numpy()
    h = hashlib.sha256()
    for a in (ids, scores):
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class FlightRecorder:
    """Bounded, sampled on-disk workload capture (see module doc).

    Thread-safe: hook sites on any thread append under one lock; SQLite
    writes happen in flush() batches on whichever thread crossed the
    `flush_every` watermark (single connection, serialized by the same
    lock)."""

    def __init__(self, path: str, *, sample_every: int = 1,
                 max_records: int = 100_000, flush_every: int = 64):
        assert sample_every >= 1, sample_every
        assert max_records >= 1, max_records
        self.path = str(path)
        self.sample_every = int(sample_every)
        self.max_records = int(max_records)
        self.flush_every = max(int(flush_every), 1)
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(self.path, check_same_thread=False,
                                     isolation_level=None)
        self._conn.executescript(_SCHEMA)
        self._conn.execute(
            "INSERT OR REPLACE INTO meta VALUES ('version', '1')")
        self._t0 = time.monotonic()
        self._seen = 0          # eligible calls (sampling denominator)
        self._seq = 0           # records actually captured
        self._buf: List[tuple] = []
        self._closed = False
        m = obs_metrics.default_registry().scope(
            component="recorder", inst=obs_metrics.next_instance())
        self._c_recorded = m.counter("records")
        self._c_dropped = m.counter("dropped")
        self._c_sampled_out = m.counter("sampled_out")

    # -- capture -------------------------------------------------------------
    def record(self, site: str, tenant: Optional[str], vecs,
               spec=None, result=None):
        """Capture one call. Called ONLY behind the hook-site branch
        (`recorder._ACTIVE is not None`), so all cost here is
        recording-ON cost. The sampling decision comes FIRST: a
        sampled-out call pays only the counter bump -- never the spec
        pickle or the result digest's device->host sync (bench_obs
        gates this path alongside the uninstalled one)."""
        with self._lock:
            if self._closed:
                return
            self._seen += 1
            if (self._seen - 1) % self.sample_every:
                self._c_sampled_out.inc()
                return
            if self._seq >= self.max_records:
                self._c_dropped.inc()
                return
            seq = self._seq
            self._seq += 1
        # heavy encode OUTSIDE the lock: the digest forces the
        # device->host transfer, and pickling walks the predicate tree
        ts = time.monotonic() - self._t0
        blob_spec = None
        if spec is not None:
            try:
                blob_spec = pickle.dumps(spec, protocol=4)
            except Exception:
                # opaque predicate callable etc. -- unreplayable; count
                # the drop rather than poison the capture file (the
                # reserved seq stays as a gap)
                self._c_dropped.inc()
                return
        digest = None if result is None else result_digest(result)
        blob_vecs, q, dim = None, 0, 0
        if vecs is not None:
            v = np.atleast_2d(np.asarray(vecs, np.float32))
            blob_vecs = np.ascontiguousarray(v).tobytes()
            q, dim = int(v.shape[0]), int(v.shape[1])
        with self._lock:
            if self._closed:
                return
            self._buf.append((seq, ts, tenant, site, blob_spec,
                              blob_vecs, q, dim, digest))
            self._c_recorded.inc()
            if len(self._buf) >= self.flush_every:
                self._flush_locked()

    def _flush_locked(self):
        if self._buf:
            self._conn.executemany(
                "INSERT INTO flight VALUES (?,?,?,?,?,?,?,?,?)",
                self._buf)
            self._buf.clear()

    def flush(self):
        with self._lock:
            self._flush_locked()

    def close(self):
        with self._lock:
            if self._closed:
                return
            self._flush_locked()
            self._closed = True
            self._conn.close()
        uninstall(self)

    def __enter__(self) -> "FlightRecorder":
        install(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    # -- introspection -------------------------------------------------------
    @property
    def recorded(self) -> int:
        return self._seq

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"path": self.path, "recorded": self._seq,
                    "seen": self._seen,
                    "dropped": self._c_dropped.value,
                    "sampled_out": self._c_sampled_out.value,
                    "sample_every": self.sample_every,
                    "max_records": self.max_records,
                    "full": self._seq >= self.max_records,
                    "closed": self._closed}


@contextlib.contextmanager
def recording(path: str, **kwargs):
    """`with recording(path) as rec:` -- create + install a recorder for
    the block, flush + uninstall on exit (the file stays for replay)."""
    rec = FlightRecorder(path, **kwargs)
    install(rec)
    try:
        yield rec
    finally:
        rec.close()


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CapturedRecord:
    """One decoded capture row."""

    seq: int
    ts_offset: float
    tenant: Optional[str]
    site: str
    spec: Optional[Any]                  # QuerySpec (unpickled) or None
    vecs: Optional[np.ndarray]           # [q, dim] float32 or None
    digest: Optional[str]


def load(path: str, *, t0: float = 0.0, t1: float = float("inf"),
         sites: Optional[Sequence[str]] = None) -> List[CapturedRecord]:
    """Decode a capture file (optionally a [t0, t1) ts_offset window
    and/or a site filter) into replay-ready records, seq-ordered."""
    conn = sqlite3.connect(path)
    try:
        rows = conn.execute(
            "SELECT seq, ts_offset, tenant, site, spec, vecs, q, dim,"
            " digest FROM flight WHERE ts_offset >= ? AND ts_offset < ?"
            " ORDER BY seq", (t0, t1)).fetchall()
    finally:
        conn.close()
    out: List[CapturedRecord] = []
    keep = None if sites is None else set(sites)
    for seq, ts, tenant, site, bspec, bvecs, q, dim, digest in rows:
        if keep is not None and site not in keep:
            continue
        spec = None if bspec is None else pickle.loads(bspec)
        vecs = None
        if bvecs is not None:
            vecs = np.frombuffer(bvecs, np.float32).reshape(q, dim).copy()
        out.append(CapturedRecord(seq=seq, ts_offset=ts, tenant=tenant,
                                  site=site, spec=spec, vecs=vecs,
                                  digest=digest))
    return out


@dataclasses.dataclass
class ReplayMismatch:
    seq: int
    site: str
    tenant: Optional[str]
    expected: str
    got: str


@dataclasses.dataclass
class ReplayReport:
    """What replay() did: every vector-carrying record re-executed, every
    digest checked. `ok` is the bit-parity verdict."""

    replayed: int = 0
    matched: int = 0
    self_checked: int = 0       # no capture digest: double-run parity
    events: int = 0             # fleet.get touches re-applied
    skipped: int = 0            # no engine resolvable for the record
    mismatches: List[ReplayMismatch] = dataclasses.field(
        default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.mismatches

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


def replay(source, *, engine=None, fleet=None, strict: bool = False,
           t0: float = 0.0, t1: float = float("inf"),
           sites: Optional[Sequence[str]] = None) -> ReplayReport:
    """Re-execute a captured window and assert bit-identical results.

    `source` is a capture path or a list of CapturedRecords. Records
    resolve to an engine by tenant through `fleet` when given (so a
    multi-tenant capture replays through the live-handle LRU exactly as
    production did, spills included), else they all run on `engine`.

    Records captured with a result digest are checked capture-vs-replay;
    digestless records (front-door admissions) are executed twice and
    the two runs checked against each other -- either way a mismatch is
    a determinism violation. `strict=True` raises AssertionError on any
    mismatch; the default returns the report for the caller to gate on.
    """
    recs = load(source, t0=t0, t1=t1, sites=sites) \
        if isinstance(source, str) else list(source)
    rep = ReplayReport()
    for r in recs:
        eng = None
        if fleet is not None and r.tenant is not None:
            eng = fleet.get(r.tenant)
        elif engine is not None:
            eng = engine
        if r.site == SITE_FLEET_GET or r.vecs is None:
            if eng is None:
                rep.skipped += 1
            else:
                rep.events += 1
            continue
        if eng is None:
            rep.skipped += 1
            continue
        got = result_digest(eng.query(r.vecs, r.spec))
        if r.digest is not None:
            expect = r.digest
        else:
            expect = result_digest(eng.query(r.vecs, r.spec))
            rep.self_checked += 1
        rep.replayed += 1
        if got == expect:
            rep.matched += 1
        else:
            rep.mismatches.append(ReplayMismatch(
                seq=r.seq, site=r.site, tenant=r.tenant,
                expected=expect, got=got))
    if strict and not rep.ok:
        m = rep.mismatches[0]
        raise AssertionError(
            f"replay diverged on {len(rep.mismatches)}/{rep.replayed} "
            f"records; first: seq={m.seq} site={m.site} "
            f"tenant={m.tenant} {m.expected[:12]} != {m.got[:12]}")
    return rep
