"""MicroNN-TPU: disk/HBM-tiered updatable vector search (MicroNN, Apple
2025) as a first-class feature of a multi-pod JAX LM framework.

Public surface:
    repro.storage.MicroNN        -- the embeddable engine (paper Fig. 1)
    repro.core                   -- C1-C6 algorithm modules
    repro.configs.get_arch       -- --arch registry (10 assigned archs)
    repro.launch.dryrun          -- multi-pod dry-run + roofline
    repro.distributed            -- pod-scale distributed ANN search
    repro.fleet.Fleet            -- multi-tenant engines, one FramePool
"""
__version__ = "1.0.0"
